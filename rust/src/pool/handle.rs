//! `PoolHandle` / `PooledVec` — the serving engine's route into the pool
//! family.
//!
//! The coordinator's hot path needs plain growable buffers (token lanes,
//! block tables, logits rows), not raw blocks. `PoolHandle` is a cheap,
//! cloneable capability that routes byte allocations either through a
//! shared [`ShardedMultiPool`] (the paper's pool speedup, thread-safe via
//! the sharded layer) or straight through the system allocator — the
//! latter exists so ablation A4 can A/B "pool-backed vs malloc-backed
//! serving path" with the *same* engine code.
//!
//! `PooledVec<T>` is the vec flavour the engine uses: fixed capacity
//! decided up front (engine geometry is static), length moves freely, and
//! the backing block returns to the pool on drop. Pushing past capacity
//! grows by doubling — correct but counted, so the steady-state tests can
//! prove it never happens on the decode path.

use core::alloc::Layout;
use core::marker::PhantomData;
use core::ops::{Deref, DerefMut};
use core::ptr::NonNull;
use std::sync::Arc;

use super::multi::{ConfigError, MultiPoolConfig, ShardedMultiPool};
use super::placement::ShardPlacement;

/// All pool-served blocks (and the system fallback inside
/// [`ShardedMultiPool`]) are 16-aligned; `PooledVec` element types must
/// not need more.
const HANDLE_ALIGN: usize = 16;

/// Where a `PooledVec`'s backing block came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Backing {
    /// Served by the handle's multi-pool (class or its system fallback);
    /// the pool resolves the exact class from the pointer on free.
    Pool,
    /// Handle is in system mode (malloc-backed ablation arm).
    System,
    /// Zero-capacity vec: nothing to free.
    Empty,
}

/// A cloneable allocation capability for the serving stack.
///
/// Built with [`PoolHandle::builder`], which routes through a shared
/// thread-safe [`ShardedMultiPool`]; [`PoolHandle::system`] routes every
/// request to the system allocator (the malloc-backed ablation arm).
#[derive(Clone)]
pub struct PoolHandle {
    inner: Option<Arc<ShardedMultiPool>>,
}

/// Builder for pool-backed [`PoolHandle`]s — the one construction path
/// that replaced the old constructor zoo (`pooled`,
/// `pooled_with_placement`, `serving_default`, `serving_uncached`,
/// `serving_with_placement`, all now thin deprecated shims).
///
/// Defaults are the serving-engine geometry: derived classes 16 B …
/// 4 KiB, 256 blocks per class, system fallback on, magazines on
/// (CAS-free per-thread hot path), spill on
/// ([`super::multi::DEFAULT_SPILL_HOPS`] hops), steal-aware shard
/// topology sized by available parallelism.
///
/// ```
/// use fastpool::pool::PoolHandle;
/// let h = PoolHandle::builder()
///     .classes([32, 48, 256])      // arbitrary monotone class table
///     .blocks_per_class(64)
///     .magazines(false)            // bare-sharded A/B arm
///     .spill(1)                    // at most one hop on exhaustion
///     .shards(2)
///     .build();
/// assert!(h.is_pooled());
/// ```
#[derive(Clone)]
pub struct PoolHandleBuilder {
    cfg: MultiPoolConfig,
    shards: Option<usize>,
    placement: Option<Arc<dyn ShardPlacement>>,
}

impl PoolHandleBuilder {
    fn new() -> Self {
        Self { cfg: serving_config(), shards: None, placement: None }
    }

    /// Replace the whole pool geometry (the other setters then tweak it).
    pub fn config(mut self, cfg: MultiPoolConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Explicit class table: arbitrary strictly-increasing block sizes
    /// (normalised to 16-byte multiples; validated at build).
    pub fn classes(mut self, classes: impl IntoIterator<Item = usize>) -> Self {
        self.cfg.classes = classes.into_iter().collect();
        self
    }

    /// Derived power-of-two class ladder `min..=max` (the default is
    /// 16 B … 4 KiB). Ignored if [`Self::classes`] was set.
    pub fn class_range(mut self, min: usize, max: usize) -> Self {
        self.cfg.min_class = min;
        self.cfg.max_class = max;
        self
    }

    pub fn blocks_per_class(mut self, blocks: u32) -> Self {
        self.cfg.blocks_per_class = blocks;
        self
    }

    /// Toggle the per-thread magazine layer (default on). Off = the
    /// bare-sharded "uncached" ablation arm: same classes, same
    /// topology, no CAS-free front.
    pub fn magazines(mut self, on: bool) -> Self {
        self.cfg.magazine_depth =
            if on { super::magazine::DEFAULT_MAG_DEPTH } else { 0 };
        self
    }

    /// Bound the cross-class spill walk on exhaustion (0 = fail fast to
    /// the system fallback; default [`super::multi::DEFAULT_SPILL_HOPS`]).
    pub fn spill(mut self, hops: u32) -> Self {
        self.cfg.spill_hops = hops;
        self
    }

    /// Route oversize/exhausted requests to the system allocator
    /// (default on; off makes exhaustion a hard allocation failure).
    pub fn system_fallback(mut self, on: bool) -> Self {
        self.cfg.system_fallback = on;
        self
    }

    /// Shard count (default: available parallelism).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = Some(shards);
        self
    }

    /// Shard-topology policy (default [`crate::pool::StealAware`];
    /// ablations pass [`crate::pool::RoundRobin`] to measure what
    /// steal-aware rehoming buys).
    pub fn placement(mut self, placement: Arc<dyn ShardPlacement>) -> Self {
        self.placement = Some(placement);
        self
    }

    /// Build, validating the configuration.
    pub fn try_build(self) -> Result<PoolHandle, ConfigError> {
        let shards = self.shards.unwrap_or_else(super::sharded::default_shards);
        let mp = match self.placement {
            Some(p) => ShardedMultiPool::try_with_placement(self.cfg, shards, p)?,
            None => ShardedMultiPool::try_with_placement(
                self.cfg,
                shards,
                Arc::new(super::placement::StealAware::default()),
            )?,
        };
        Ok(PoolHandle { inner: Some(Arc::new(mp)) })
    }

    /// Build, panicking on an invalid configuration (delegates to
    /// [`Self::try_build`]).
    pub fn build(self) -> PoolHandle {
        self.try_build().expect("invalid PoolHandleBuilder configuration")
    }
}

/// The serving-engine pool geometry — the builder's starting point.
fn serving_config() -> MultiPoolConfig {
    MultiPoolConfig {
        min_class: 16,
        max_class: 4096,
        blocks_per_class: 256,
        ..Default::default()
    }
}

impl PoolHandle {
    /// Start building a pool-backed handle (serving defaults; see
    /// [`PoolHandleBuilder`]).
    pub fn builder() -> PoolHandleBuilder {
        PoolHandleBuilder::new()
    }

    /// Pool-backed handle over a fresh [`ShardedMultiPool`] (steal-aware
    /// topology by default).
    #[deprecated(since = "0.6.0", note = "use PoolHandle::builder().config(cfg).shards(n)")]
    pub fn pooled(cfg: MultiPoolConfig, shards: usize) -> Self {
        Self::builder().config(cfg).shards(shards).build()
    }

    /// As `pooled` with an explicit shard-topology policy.
    #[deprecated(
        since = "0.6.0",
        note = "use PoolHandle::builder().config(cfg).shards(n).placement(p)"
    )]
    pub fn pooled_with_placement(
        cfg: MultiPoolConfig,
        shards: usize,
        placement: Arc<dyn ShardPlacement>,
    ) -> Self {
        Self::builder().config(cfg).shards(shards).placement(placement).build()
    }

    /// Share an existing multi-pool.
    pub fn from_multi(multi: Arc<ShardedMultiPool>) -> Self {
        Self { inner: Some(multi) }
    }

    /// Pool-backed handle sized for the serving engine.
    #[deprecated(since = "0.6.0", note = "use PoolHandle::builder().build()")]
    pub fn serving_default() -> Self {
        Self::builder().build()
    }

    /// Serving geometry with the magazine layer disabled.
    #[deprecated(since = "0.6.0", note = "use PoolHandle::builder().magazines(false)")]
    pub fn serving_uncached() -> Self {
        Self::builder().magazines(false).build()
    }

    /// Serving geometry with an explicit shard-topology policy.
    #[deprecated(since = "0.6.0", note = "use PoolHandle::builder().placement(p)")]
    pub fn serving_with_placement(placement: Arc<dyn ShardPlacement>) -> Self {
        Self::builder().placement(placement).build()
    }

    /// Malloc-backed handle: every allocation goes to the system
    /// allocator. The ablation baseline — same engine code, no pool.
    pub fn system() -> Self {
        Self { inner: None }
    }

    pub fn is_pooled(&self) -> bool {
        self.inner.is_some()
    }

    /// The backing multi-pool, when pooled (metrics export, stats).
    pub fn multi(&self) -> Option<&ShardedMultiPool> {
        self.inner.as_deref()
    }

    // ---- traversal --------------------------------------------------------
    //
    // The handle is the engine-facing end of the `Traverse` lineage:
    // these passthroughs answer "what is allocated right now?" without
    // exposing the pool itself. A system-mode handle has no grid to walk
    // (and blocks served by a pooled handle's *system fallback* live
    // outside every class region), so these cover exactly the pool-served
    // blocks — the same set `num_free` accounts for.

    /// Number of live pool-served blocks. 0 for system-mode handles.
    /// Exact at quiescence or under [`Self::pin_for_traversal`].
    pub fn live_count(&self) -> u32 {
        use super::traverse::Traverse;
        self.inner.as_deref().map_or(0, |mp| mp.live_count())
    }

    /// Visit every live pool-served block (ascending grid order, class
    /// attributed). No-op for system-mode handles.
    pub fn for_each_live(&self, f: impl FnMut(super::traverse::LiveBlock)) {
        use super::traverse::Traverse;
        if let Some(mp) = self.inner.as_deref() {
            mp.for_each_live(f);
        }
    }

    /// Materialise the live set. Empty for system-mode handles.
    pub fn live_snapshot(&self) -> Vec<super::traverse::LiveBlock> {
        use super::traverse::Traverse;
        self.inner.as_deref().map_or_else(Vec::new, |mp| mp.live_snapshot())
    }

    /// Park allocation on the backing pool while traversing (`None` for
    /// system-mode handles). The pinning thread must not allocate from
    /// this handle while the pin is held.
    pub fn pin_for_traversal(&self) -> Option<super::multi::MultiTraversalPin<'_>> {
        self.inner.as_deref().map(|mp| mp.pin_for_traversal())
    }

    /// Allocate `size` bytes at 16-alignment. `size` must be non-zero.
    fn alloc_bytes(&self, size: usize) -> Option<(NonNull<u8>, Backing)> {
        debug_assert!(size > 0);
        match &self.inner {
            Some(mp) => mp.allocate(size).map(|(p, _origin)| (p, Backing::Pool)),
            None => {
                let layout = Layout::from_size_align(size, HANDLE_ALIGN).ok()?;
                // SAFETY: `layout` has non-zero size (`size > 0` is the caller contract,
                // asserted above).
                NonNull::new(unsafe { std::alloc::alloc(layout) })
                    .map(|p| (p, Backing::System))
            }
        }
    }

    /// # Safety
    /// `(p, size, backing)` must match a live allocation from
    /// [`Self::alloc_bytes`] on this handle (or a clone of it).
    unsafe fn dealloc_bytes(&self, p: NonNull<u8>, size: usize, backing: Backing) {
        match backing {
            Backing::Pool => {
                // The pool resolves the serving class from the pointer
                // (address-sorted binary search) — no origin to carry.
                self.inner
                    .as_ref()
                    .expect("pool-backed block freed through a system handle")
                    .deallocate(p, size);
            }
            Backing::System => {
                let layout = Layout::from_size_align(size, HANDLE_ALIGN)
                    .expect("layout was valid at alloc");
                std::alloc::dealloc(p.as_ptr(), layout);
            }
            Backing::Empty => {}
        }
    }
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle").field("pooled", &self.is_pooled()).finish()
    }
}

/// A fixed-capacity vector whose backing block comes from a
/// [`PoolHandle`]. `T: Copy` keeps drops trivial — exactly the payloads
/// the serving path moves (token ids, lens, table rows, logits).
pub struct PooledVec<T: Copy> {
    ptr: NonNull<u8>,
    /// Capacity in elements. 0 ⇒ `ptr` dangles and nothing is freed.
    cap: usize,
    len: usize,
    /// High-water mark of initialised elements (`max` of every `len` ever
    /// reached): [`Self::set_len_initialized`] may expose up to here
    /// without repainting.
    init: usize,
    backing: Backing,
    handle: PoolHandle,
    _marker: PhantomData<T>,
}

// SAFETY: PooledVec owns its block exclusively; the handle's pools are
// Sync, so moving/sharing follows the element type.
unsafe impl<T: Copy + Send> Send for PooledVec<T> {}
// SAFETY: shared access only reads through `&self`; interior mutation
// requires `&mut`, so `Sync` follows the element type too.
unsafe impl<T: Copy + Sync> Sync for PooledVec<T> {}

impl<T: Copy> PooledVec<T> {
    /// Empty vec with `cap` elements of room taken from `handle`.
    pub fn with_capacity(handle: &PoolHandle, cap: usize) -> Self {
        assert!(
            core::mem::align_of::<T>() <= HANDLE_ALIGN,
            "PooledVec element alignment exceeds pool block alignment"
        );
        assert!(core::mem::size_of::<T>() > 0, "PooledVec does not support ZSTs");
        if cap == 0 {
            return Self {
                // T-aligned dangling pointer: `as_slice` feeds it to
                // `from_raw_parts`, which demands alignment even for
                // length-0 slices.
                ptr: NonNull::<T>::dangling().cast::<u8>(),
                cap: 0,
                len: 0,
                init: 0,
                backing: Backing::Empty,
                handle: handle.clone(),
                _marker: PhantomData,
            };
        }
        let bytes = cap
            .checked_mul(core::mem::size_of::<T>())
            .expect("PooledVec capacity overflows usize");
        let (ptr, backing) = handle
            .alloc_bytes(bytes)
            .expect("PooledVec backing allocation failed");
        Self { ptr, cap, len: 0, init: 0, backing, handle: handle.clone(), _marker: PhantomData }
    }

    /// Empty vec bound to `handle` with no backing block (useful as the
    /// `mem::take` placeholder for reusable buffers).
    pub fn new(handle: &PoolHandle) -> Self {
        Self::with_capacity(handle, 0)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn as_slice(&self) -> &[T] {
        // SAFETY: 0..len are initialised (only push/resize advance len).
        unsafe { core::slice::from_raw_parts(self.ptr.as_ptr() as *const T, self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as above; &mut self gives exclusive access.
        unsafe { core::slice::from_raw_parts_mut(self.ptr.as_ptr() as *mut T, self.len) }
    }

    /// Append, growing (pool re-allocation) only past the fixed capacity.
    pub fn push(&mut self, v: T) {
        if self.len == self.cap {
            self.grow((self.cap * 2).max(4));
        }
        // SAFETY: len < cap after the growth check.
        let slot = unsafe { (self.ptr.as_ptr() as *mut T).add(self.len) };
        // SAFETY: the slot is inside the buffer and unaliased (&mut self).
        unsafe { slot.write(v) };
        self.len += 1;
        self.init = self.init.max(self.len);
    }

    pub fn extend_from_slice(&mut self, xs: &[T]) {
        if self.len + xs.len() > self.cap {
            self.grow((self.len + xs.len()).max(self.cap * 2));
        }
        // SAFETY: len stays within cap after the growth check.
        let dst = unsafe { (self.ptr.as_ptr() as *mut T).add(self.len) };
        // SAFETY: room for xs.len() more elements; src and dst are disjoint
        // (xs borrows another allocation; &mut self owns this one).
        unsafe { core::ptr::copy_nonoverlapping(xs.as_ptr(), dst, xs.len()) };
        self.len += xs.len();
        self.init = self.init.max(self.len);
    }

    /// Set length to `n`, filling every slot with `v` (the step buffers'
    /// "clear and repaint the lane" idiom). Grows only past capacity.
    pub fn fill_with(&mut self, n: usize, v: T) {
        if n > self.cap {
            self.grow(n.max(self.cap * 2));
        }
        self.len = n;
        self.init = self.init.max(self.len);
        self.as_mut_slice().fill(v);
    }

    /// Set the length to `n` WITHOUT touching contents — the write-only
    /// out-buffer idiom (e.g. a logits buffer the backend fully
    /// overwrites), skipping `fill_with`'s memset on the hot path.
    ///
    /// Safe because only already-initialised storage may be exposed:
    /// panics if `n` exceeds the high-water initialised length (pre-fill
    /// once with [`Self::fill_with`] at construction).
    pub fn set_len_initialized(&mut self, n: usize) {
        assert!(
            n <= self.init,
            "set_len_initialized({n}) past initialised high-water {}",
            self.init
        );
        self.len = n;
    }

    pub fn truncate(&mut self, n: usize) {
        if n < self.len {
            self.len = n;
        }
    }

    /// Re-seat the vec on a block of at least `new_cap` elements.
    fn grow(&mut self, new_cap: usize) {
        debug_assert!(new_cap > self.cap);
        let fresh = {
            let mut v = Self::with_capacity(&self.handle, new_cap);
            v.extend_from_slice(self.as_slice());
            v
        };
        *self = fresh; // old self drops, returning its block
    }
}

impl<T: Copy> Drop for PooledVec<T> {
    fn drop(&mut self) {
        if self.cap > 0 {
            let bytes = self.cap * core::mem::size_of::<T>();
            // SAFETY: (ptr, bytes, backing) is the live allocation made in
            // with_capacity on this handle.
            unsafe { self.handle.dealloc_bytes(self.ptr, bytes, self.backing) };
        }
    }
}

impl<T: Copy> Deref for PooledVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy> DerefMut for PooledVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

/// A zero-capacity system-mode placeholder — what `mem::take` leaves
/// behind when a reusable buffer is temporarily moved out of a struct.
impl<T: Copy> Default for PooledVec<T> {
    fn default() -> Self {
        Self::new(&PoolHandle::system())
    }
}

impl<T: Copy> Clone for PooledVec<T> {
    fn clone(&self) -> Self {
        let mut v = Self::with_capacity(&self.handle, self.cap.max(self.len));
        v.extend_from_slice(self.as_slice());
        v
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for PooledVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

impl<T: Copy + PartialEq> PartialEq for PooledVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_handle() -> PoolHandle {
        PoolHandle::builder().class_range(16, 256).blocks_per_class(8).shards(2).build()
    }

    #[test]
    fn placement_choice_flows_through_handle() {
        use crate::pool::placement::RoundRobin;
        let h = PoolHandle::builder().placement(Arc::new(RoundRobin)).build();
        assert_eq!(h.multi().unwrap().placement_name(), "round_robin");
        let d = PoolHandle::builder().build();
        assert_eq!(d.multi().unwrap().placement_name(), "steal_aware");
    }

    #[test]
    fn serving_default_is_cached_and_uncached_arm_is_not() {
        let cached = PoolHandle::builder().build();
        assert!(cached.multi().unwrap().magazines_enabled());
        let bare = PoolHandle::builder().magazines(false).build();
        assert!(!bare.multi().unwrap().magazines_enabled());
        // Both arms serve the same vec workload through the same code.
        for h in [cached, bare] {
            let mut v: PooledVec<u32> = PooledVec::with_capacity(&h, 8);
            v.extend_from_slice(&[1, 2, 3]);
            assert_eq!(v.as_slice(), &[1, 2, 3]);
        }
    }

    #[test]
    fn builder_explicit_classes_and_spill_flow_through() {
        let h = PoolHandle::builder()
            .classes([32, 48, 256])
            .blocks_per_class(4)
            .spill(1)
            .shards(1)
            .build();
        let mp = h.multi().unwrap();
        assert_eq!(mp.num_classes(), 3);
        assert_eq!(mp.class_size(1), 48);
        // Exhaust the 48B class; spill(1) reaches the 256B class.
        let mut held = Vec::new();
        for _ in 0..4 {
            let (p, _) = mp.allocate(48).unwrap();
            held.push(p);
        }
        let (p, _) = mp.allocate(48).unwrap();
        assert_eq!(mp.spill_total(), 1);
        assert_eq!(mp.class_of_ptr(p), Some(2));
        // SAFETY: `p` came from `allocate(48)` and is freed exactly once.
        unsafe { mp.deallocate(p, 48) };
        for p in held {
            // SAFETY: likewise for every held pointer.
            unsafe { mp.deallocate(p, 48) };
        }
    }

    #[test]
    fn builder_validates_config() {
        assert!(PoolHandle::builder().blocks_per_class(0).try_build().is_err());
        assert!(PoolHandle::builder().classes([64, 64]).try_build().is_err());
        assert!(PoolHandle::builder().class_range(24, 4096).try_build().is_err());
        assert!(PoolHandle::builder().classes([16, 48]).try_build().is_ok());
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_still_work() {
        // The old zoo must keep building equivalent handles until callers
        // finish migrating to the builder.
        let p = PoolHandle::pooled(
            MultiPoolConfig { blocks_per_class: 8, ..Default::default() },
            2,
        );
        assert!(p.is_pooled());
        let d = PoolHandle::serving_default();
        assert!(d.multi().unwrap().magazines_enabled());
        let u = PoolHandle::serving_uncached();
        assert!(!u.multi().unwrap().magazines_enabled());
        let r = PoolHandle::serving_with_placement(Arc::new(
            crate::pool::placement::RoundRobin,
        ));
        assert_eq!(r.multi().unwrap().placement_name(), "round_robin");
    }

    #[test]
    fn push_index_slice_roundtrip() {
        for handle in [small_handle(), PoolHandle::system()] {
            let mut v: PooledVec<i32> = PooledVec::with_capacity(&handle, 8);
            assert!(v.is_empty());
            for i in 0..8 {
                v.push(i);
            }
            assert_eq!(v.len(), 8);
            assert_eq!(v[3], 3);
            assert_eq!(&v[..2], &[0, 1]);
            v[5] = 50;
            assert_eq!(v.as_slice()[5], 50);
            v.clear();
            assert!(v.is_empty());
        }
    }

    #[test]
    fn pooled_blocks_come_from_the_pool_and_return() {
        let handle = small_handle();
        let mp_hits = |h: &PoolHandle| {
            let mp = h.multi().unwrap();
            (0..mp.num_classes()).map(|c| mp.class_hits(c)).sum::<u64>()
        };
        let before = mp_hits(&handle);
        {
            let mut v: PooledVec<u64> = PooledVec::with_capacity(&handle, 4); // 32 B class
            v.push(7);
            assert_eq!(mp_hits(&handle), before + 1, "backing must be pool-served");
        }
        // Block back in the pool: same-size vec is another pool hit.
        let _v2: PooledVec<u64> = PooledVec::with_capacity(&handle, 4);
        assert_eq!(mp_hits(&handle), before + 2);
    }

    #[test]
    fn grow_preserves_contents_past_fixed_capacity() {
        let handle = small_handle();
        let mut v: PooledVec<i32> = PooledVec::with_capacity(&handle, 2);
        for i in 0..40 {
            v.push(i);
        }
        assert_eq!(v.len(), 40);
        assert!(v.capacity() >= 40);
        assert_eq!(v.as_slice(), (0..40).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn fill_with_and_truncate() {
        let handle = small_handle();
        let mut v: PooledVec<i32> = PooledVec::with_capacity(&handle, 16);
        v.fill_with(10, -1);
        assert_eq!(v.len(), 10);
        assert!(v.iter().all(|&x| x == -1));
        v.truncate(3);
        assert_eq!(v.len(), 3);
        v.fill_with(16, 9); // repaint to full capacity, no grow
        assert_eq!(v.capacity(), 16);
        assert_eq!(v[15], 9);
    }

    #[test]
    fn set_len_initialized_reuses_painted_storage() {
        let handle = small_handle();
        let mut v: PooledVec<f32> = PooledVec::with_capacity(&handle, 8);
        v.fill_with(8, 1.5); // paint the full capacity once
        v.clear();
        v.set_len_initialized(5); // pure length change, no memset
        assert_eq!(v.len(), 5);
        assert!(v.iter().all(|&x| x == 1.5), "contents untouched");
        v.set_len_initialized(8);
        assert_eq!(v.len(), 8);
    }

    #[test]
    #[should_panic(expected = "initialised high-water")]
    fn set_len_initialized_rejects_unpainted_tail() {
        let handle = small_handle();
        let mut v: PooledVec<i32> = PooledVec::with_capacity(&handle, 8);
        v.fill_with(3, 0);
        v.set_len_initialized(4); // 3 initialised, 4 requested → panic
    }

    #[test]
    fn clone_and_eq_across_handles() {
        let handle = small_handle();
        let mut v: PooledVec<u32> = PooledVec::with_capacity(&handle, 4);
        v.extend_from_slice(&[1, 2, 3]);
        let w = v.clone();
        assert_eq!(v, w);
        assert_eq!(w.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn zero_capacity_and_take_placeholder() {
        let handle = small_handle();
        let mut v: PooledVec<i32> = PooledVec::new(&handle);
        assert_eq!(v.capacity(), 0);
        v.push(5); // grows from empty
        assert_eq!(v.as_slice(), &[5]);
        let w: PooledVec<i32> = PooledVec::new(&PoolHandle::system());
        drop(w); // nothing to free
    }

    #[test]
    fn oversize_requests_fall_through_but_work() {
        let handle = small_handle(); // max class 256 B
        let mut v: PooledVec<u64> = PooledVec::with_capacity(&handle, 1024); // 8 KiB
        for i in 0..1024u64 {
            v.push(i);
        }
        assert_eq!(v[1023], 1023);
        assert!(
            handle.multi().unwrap().system_allocs.load(core::sync::atomic::Ordering::Relaxed)
                >= 1,
            "oversize block must be system-served"
        );
    }

    #[test]
    fn concurrent_pooled_vecs_distinct_backing() {
        let handle = PoolHandle::builder()
            .class_range(16, 256)
            .blocks_per_class(512)
            .system_fallback(false)
            .shards(4)
            .build();
        std::thread::scope(|s| {
            for t in 0..4i32 {
                let handle = handle.clone();
                s.spawn(move || {
                    for round in 0..200 {
                        let mut v: PooledVec<i32> =
                            PooledVec::with_capacity(&handle, 8);
                        v.fill_with(8, t * 1000 + round);
                        assert!(v.iter().all(|&x| x == t * 1000 + round));
                    }
                });
            }
        });
    }
}
