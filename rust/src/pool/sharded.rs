//! `ShardedPool` — the scaling answer to the single-CAS bottleneck.
//!
//! [`AtomicPool`](super::atomic::AtomicPool) solves §VI's threading
//! limitation with one Treiber stack, but its single packed head word is a
//! contention hot-spot: every allocate/free from every thread CASes the
//! same cache line, so throughput *degrades* as cores are added (ablation
//! A3). Following the per-thread-structure direction of Blelloch & Wei,
//! *Concurrent Fixed-Size Allocation and Free in Constant Time*
//! (arXiv:2008.04296), this module stripes one region across N independent
//! `AtomicPool` shards:
//!
//! * **Routing** — each thread owns a *home slot* leased from a small
//!   process-wide recyclable free-list (see *Home-slot lifecycle* below);
//!   a [`ShardPlacement`](super::placement::ShardPlacement) policy maps
//!   the slot to a shard. The hot path costs one const-init TLS read plus
//!   one relaxed load of the pool's per-slot home map, and never
//!   allocates. Threads ≤ shards ⇒ zero CAS sharing.
//! * **Batched stealing** — on local exhaustion the allocator scans
//!   sibling shards, so capacity is pooled, not partitioned: one thread
//!   can still drain the entire pool. Each successful scan detaches up to
//!   *k* blocks from the victim in one tag-guarded CAS (Blelloch & Wei's
//!   batch-transfer amortisation): one block is returned to the caller
//!   and the extras are parked in the home slot's **steal stash**, a
//!   Treiber stack of grid indices that is checked before the next scan.
//!   *k* adapts to the recent steal rate — it doubles after every
//!   successful scan (up to [`MAX_STEAL_BATCH`]) and halves on a local
//!   hit, so a thread in a steady cross-shard regime pays one scan per
//!   *k* allocations while a balanced pool keeps k = 1 and steals no
//!   more than it needs. Scans, stolen blocks and stash hits are counted
//!   per home shard — the "concurrency tax" visible in
//!   [`ShardedPoolStats`].
//! * **Steal-aware rehoming** — with a
//!   [`StealAware`](super::placement::StealAware) placement (the
//!   default), each home shard also keeps a *windowed* local-hit vs.
//!   per-victim steal profile. When a window of
//!   [`DEFAULT_REHOME_WINDOW`](super::placement::DEFAULT_REHOME_WINDOW)
//!   allocations closes with one victim shard supplying ≥
//!   [`DEFAULT_REHOME_THRESHOLD_PCT`](super::placement::DEFAULT_REHOME_THRESHOLD_PCT)%
//!   of them, the thread that closed the window is rehomed to that
//!   victim: its own home-map entry is swung with a single
//!   generation-stamped CAS (no other thread's routing changes, and a
//!   racing rehome/reassignment loses the CAS cleanly), the abandoned
//!   home's steal stash is drained back to the owning shards, and the
//!   move shows up in the `rehomes`/`stash_drained` counters and the
//!   `rehome*` gauges. A thread stuck in a >50% cross-shard regime thus
//!   converges back to the paper's one-CAS local fast path instead of
//!   paying a steal scan forever.
//! * **O(1) free with no hardware divide** — shards are laid out at a
//!   uniform power-of-two *stride* (in blocks) inside one contiguous
//!   region, so `deallocate` recovers the owning shard from the pointer
//!   offset alone: the offset is exact-divided by `block_size` with the
//!   same shift + multiplicative-inverse trick as
//!   [`RawPool`](super::raw::RawPool) (§Perf), then shard = index >>
//!   stride_shift and local index = index & (stride-1). No shard id is
//!   stored in the block; the paper's zero-header property is preserved.
//!
//! ### Home-slot lifecycle (churn safety)
//!
//! Home slots used to come from a monotone global counter, so every
//! short-lived thread consumed a fresh id forever and slot assignment
//! drifted with churn. Slots are now leased from a process-wide
//! free-list over a fixed arena of [`MAX_HOME_SLOTS`] ids: a thread takes
//! the lowest recycled id (or a fresh one) on first use and a TLS guard
//! returns it at thread exit, bumping the slot's generation and the
//! global [`home_slot_epoch`]. Beyond `MAX_HOME_SLOTS` concurrently live
//! threads, overflow ids are shared round-robin (never recycled — they
//! are already shared, and sharing a routing hint is harmless). The
//! generation stamp makes recycling race-free: a pool's per-slot home map
//! entry records the generation it was written under, so a recycled
//! slot's new owner never inherits routing state (or rehoming history)
//! from the dead thread — the first use under the new generation rebinds
//! the entry from the placement policy.
//!
//! Stash chains a dead thread left behind stay *reachable* at all times
//! (the allocate slow path raids every stash before failing), so no block
//! is ever lost to churn; [`ShardedPool::drain_stashes`] (called by the
//! serving engine's periodic maintenance and on rehome) additionally
//! returns them to their owning shards' free lists so local fast paths
//! see them again.
//!
//! ### Memory accounting (the concurrency tax, itemised)
//!
//! * 4 bytes/block side tables (inherited from `AtomicPool`).
//! * Three cache lines of counters per shard: the hit/steal/free tallies
//!   and rehome window on the first two, and the steal-stash head —
//!   CASed by arbitrary threads — isolated on its own trailing line so
//!   cross-thread stash traffic never invalidates the owner's tally
//!   lines. Shards themselves are `CachePadded` for the same reason: two
//!   Treiber heads must never share a line.
//! * **Home map**: 8 bytes per home slot (`MAX_HOME_SLOTS` entries) for
//!   the generation-stamped slot→shard routing, plus a `shards²`-entry
//!   window matrix for the per-victim steal profile. Both are fixed-size
//!   and reported by [`ShardedPool::overhead_bytes`].
//! * **Batched-steal side table**: 4 bytes per *grid slot* (`shards ×
//!   stride`, so stride padding is included) for the stash next-links.
//!   Like the shard side tables these live outside user blocks — a stale
//!   stash reader may inspect the link of a block already handed to user
//!   code, so the link must stay in memory the user never owns.
//! * Stride padding: when `num_blocks / shards` is not a power of two the
//!   region is laid out with up-to-2× *virtual* slack between shards.
//!   Padding blocks are **never touched** — creation is lazy exactly as in
//!   the paper (§IV) — so on demand-paged systems they cost address space,
//!   not resident memory. [`ShardedPool::padded_bytes`] reports the slack
//!   so benchmarks can account for it honestly.
//! * **Transfer latency**: a batch in flight (detached from the victim,
//!   not yet published in the stash) is invisible for a few instructions;
//!   a concurrent scan can momentarily see fewer free blocks than exist.
//!   Allocation failure is therefore "every shard and stash looked empty
//!   during the scan", exactly as a single-block steal can race a free.
//!
//! ### Gauges
//!
//! [`ShardedPool::export_metrics`] publishes, per prefix: `shards`,
//! `free_blocks`, `steals_total`, `steal_scans_total`, `stash_hits_total`,
//! `stash_blocks`, **`rehomes_total`** (home-map switches performed by the
//! steal-aware policy), **`stash_drained_total`** (blocks returned to
//! their owning shards by rehome/maintenance drains), **`local_hit_pct`**
//! (share of allocations served by the caller's home shard) and per-shard
//! `shardN.{local_hits,steals,free}`. Through the serving engine these
//! appear under `pool.serving.c<class>.*`, with `pool.serving.rehomes_total`
//! aggregated across classes.

use core::alloc::Layout;
use core::cell::Cell;
use core::ptr::NonNull;
use std::sync::Arc;

use super::atomic::AtomicPool;
use super::placement::{ShardPlacement, StealAware};
use super::proto::lease::{Lease, LeaseRegistry};
use super::proto::rehome::GenEntry;
use super::proto::stash::{CountedStash, Stash};
use crate::sync::{AtomicU32, AtomicU64, Ordering};
use super::raw::{mod_inverse_u64, MIN_BLOCK_SIZE};
use super::stats::{MagazineStats, ShardStats, ShardedPoolStats};
use crate::metrics::Metrics;
use crate::util::align::{align_up, next_pow2};
use crate::util::CachePadded;

// ---------------------------------------------------------------------------
// Process-wide home-slot registry. The lease protocol itself (recyclable
// free-list, generation bumps, overflow sharing) lives in
// `proto::lease` as checkable state machines; this module owns the one
// static arena instance plus the TLS binding and exit guard.
// ---------------------------------------------------------------------------

/// Size of the home-slot arena: the number of concurrently live threads
/// that get private, recyclable routing slots. Beyond this, slots are
/// shared round-robin (harmless — a slot is only a routing hint).
pub const MAX_HOME_SLOTS: usize = 256;

/// High bit of a TLS slot word: the slot is shared (overflow or acquired
/// during thread teardown) — never recycled, excluded from rehoming (and
/// from the per-thread magazine layer, which needs exclusive slots).
pub(crate) const SLOT_SHARED_BIT: u32 = 1 << 31;

/// TLS sentinel: no slot acquired yet.
const HOME_UNSET: u64 = u64::MAX;
/// TLS sentinel: the exit guard ran; any later use takes a shared slot.
const HOME_EXITED: u64 = u64::MAX - 1;

/// The process-wide slot arena (lock-free and allocation-free, so it is
/// safe to run inside a `#[global_allocator]`).
static HOME_SLOTS: LeaseRegistry<MAX_HOME_SLOTS> = LeaseRegistry::new();

std::thread_local! {
    /// This thread's home slot, packed `(gen << 32) | slot_with_flags`.
    /// Const-init `Cell<u64>` carries no destructor, so reading it inside
    /// a `#[global_allocator]` (or another key's TLS destructor) cannot
    /// recurse into allocation.
    static HOME: Cell<u64> = const { Cell::new(HOME_UNSET) };
    /// Exit guard returning the slot to the registry. Kept separate from
    /// `HOME` so the hot path never touches a destructor-bearing key.
    static HOME_GUARD: Cell<Option<HomeGuard>> = const { Cell::new(None) };
}

struct HomeGuard(u32);

impl Drop for HomeGuard {
    fn drop(&mut self) {
        // Mark the cached slot dead *before* recycling it, so allocations
        // from later-running TLS destructors fall back to a shared slot
        // instead of racing the id's next owner.
        HOME.with(|h| h.set(HOME_EXITED));
        release_slot(self.0);
    }
}

/// Pop a recycled slot, else claim a fresh one; `(slot, privately_owned)`.
/// Drives `proto::lease`'s [`Acquire`](super::proto::lease::Acquire)
/// machine — the code the model checker interleaves step by step.
fn acquire_slot() -> (u32, bool) {
    HOME_SLOTS.acquire()
}

fn overflow_slot() -> u32 {
    HOME_SLOTS.shared_slot()
}

/// Return a slot, bumping its generation *before* recycling the id (the
/// [`Release`](super::proto::lease::Release) machine — see its state
/// docs for the ordering argument the magazine layer relies on).
fn release_slot(slot: u32) {
    HOME_SLOTS.release(slot);
}

/// This thread's `(slot_with_flags, generation)`, acquiring on first use.
#[inline]
fn home_slot() -> (u32, u32) {
    HOME.with(|h| {
        let v = h.get();
        if v != HOME_UNSET && v != HOME_EXITED {
            ((v & u32::MAX as u64) as u32, (v >> 32) as u32)
        } else {
            init_home_slot(h, v == HOME_EXITED)
        }
    })
}

#[cold]
fn init_home_slot(h: &Cell<u64>, teardown: bool) -> (u32, u32) {
    let (slot, owned) =
        if teardown { (overflow_slot(), false) } else { acquire_slot() };
    let gen = HOME_SLOTS.generation_relaxed(slot as usize);
    let flagged = if owned { slot } else { slot | SLOT_SHARED_BIT };
    h.set(((gen as u64) << 32) | flagged as u64);
    if owned {
        // Register the exit guard AFTER the cell is set: if registering a
        // destructor-bearing TLS key allocates (it can on some platforms),
        // the re-entrant allocation reads the cell and returns without
        // touching the guard key. During thread teardown `try_with` fails
        // and the slot simply stays out of circulation.
        let _ = HOME_GUARD.try_with(|g| g.set(Some(HomeGuard(slot))));
    }
    (flagged, gen)
}

/// This thread's `(slot_with_flags, generation)` — shared with the
/// magazine layer, so shard routing and the per-thread block cache key
/// off the same home-slot lease (one TLS read serves both).
#[inline]
pub(crate) fn current_slot() -> (u32, u32) {
    home_slot()
}

/// Current generation of a home slot. Acquire: pairs with the Release
/// bump in `release_slot`, so a reclaimer that observes a newer
/// generation than a cached owner stamp also sees every per-slot write
/// the exited owner made (the magazine layer's stale-flush relies on
/// this edge).
pub(crate) fn slot_generation(slot: usize) -> u32 {
    HOME_SLOTS.generation(slot)
}

/// Highest number of home-slot ids ever live at once (clamped to the
/// arena). Flat across thread churn — the recycling proof the stress
/// suite asserts.
pub fn home_slots_high_water() -> usize {
    HOME_SLOTS.high_water()
}

/// Slot ids currently parked in the recycle free-list.
pub fn home_slots_free() -> usize {
    HOME_SLOTS.free_slots()
}

/// Monotone thread-churn counter: bumps every time a thread exits and
/// returns its home slot.
pub fn home_slot_epoch() -> u64 {
    HOME_SLOTS.epoch()
}

/// Default shard count: available parallelism rounded up to a power of
/// two, capped at 64 (past that the steal scan costs more than the
/// contention it avoids).
pub fn default_shards() -> usize {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    next_pow2(n).min(64)
}

/// Upper bound on the adaptive steal batch (blocks moved per scan).
pub const MAX_STEAL_BATCH: u32 = 16;

/// Sentinel for an empty stash / end of a stash chain (grid index space;
/// same value as the proto machines' `NIL`).
const GRID_NIL: u32 = u32::MAX;

/// Per-shard counters plus the home slot's steal stash, adaptive batch
/// width and rehome window. `repr(C, align(64))` with the stash on its
/// own trailing line: the tally fields (written by threads homed here)
/// never share a line with the stash head (CASed by any thread) or with
/// a neighbouring shard's counters.
#[repr(C, align(64))]
struct ShardCounters {
    /// Allocations served by this shard for threads homed on it.
    local_hits: AtomicU64,
    /// Blocks taken from siblings by threads homed here (incl. extras).
    steals: AtomicU64,
    /// Sibling scans that found a victim (one block returned per scan).
    steal_scans: AtomicU64,
    /// Allocations served from this home's steal stash.
    stash_hits: AtomicU64,
    /// Allocations that failed after scanning every shard and stash.
    failures: AtomicU64,
    /// Frees routed to this shard by pointer decode.
    frees: AtomicU64,
    /// Threads rehomed away from this shard by the placement policy.
    rehomes: AtomicU64,
    /// Stash blocks returned to their owning shards by drains.
    stash_drained: AtomicU64,
    /// Adaptive steal batch k ∈ [1, MAX_STEAL_BATCH].
    steal_batch: AtomicU32,
    /// Allocations in the current rehome-decision window.
    win_ops: AtomicU32,
    /// The cross-thread-CASed stash head, on its own line: the head is
    /// CASed by *arbitrary* threads (batch imports, raids, drains) while
    /// the tally fields above are bumped by threads homed here, and
    /// co-locating them made every cross-thread stash CAS invalidate the
    /// owner's hot counter line. `CountedStash`'s own `align(64)` pushes
    /// it past the tally fields.
    stash: CountedStash,
}

impl ShardCounters {
    fn new() -> Self {
        Self {
            local_hits: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            steal_scans: AtomicU64::new(0),
            stash_hits: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            rehomes: AtomicU64::new(0),
            stash_drained: AtomicU64::new(0),
            steal_batch: AtomicU32::new(1),
            win_ops: AtomicU32::new(0),
            stash: CountedStash::new(),
        }
    }
}

/// A lock-free pool striped over power-of-two `AtomicPool` shards.
///
/// `Sync`: share by reference or `Arc`; all operations take `&self`.
pub struct ShardedPool {
    /// Each shard on its own cache line: the Treiber head inside an
    /// `AtomicPool` is the hottest CAS word in the pool, and packing
    /// shards back-to-back would false-share neighbouring heads.
    shards: Box<[CachePadded<AtomicPool>]>,
    counters: Box<[ShardCounters]>,
    /// Stash next-links, indexed by grid index (shard << stride_shift |
    /// local). Side table for the same reason as `AtomicPool::next`: a
    /// stale stash reader may inspect the link of a block already handed
    /// to user code.
    steal_next: Box<[AtomicU32]>,
    /// Topology policy: initial slot→shard placement + rehome rule.
    placement: Arc<dyn ShardPlacement>,
    /// Cached `placement.window()` (0 ⇒ no windowed accounting at all).
    window: u32,
    /// Per-slot routing: generation-stamped `(target shard, slot
    /// generation)` entries (`proto::rehome`). A stale stamp (slot
    /// recycled since the entry was written) forces a rebind from the
    /// placement policy, so routing state never leaks across thread
    /// lifetimes.
    home_map: Box<[GenEntry]>,
    /// Windowed per-victim steal counts, row-major `[home][victim]`.
    win_steals: Box<[AtomicU32]>,
    mem_start: NonNull<u8>,
    layout: Layout,
    block_size: usize,
    num_blocks: u32,
    /// `shards.len() - 1` (shard count is a power of two).
    shard_mask: usize,
    /// log2 of the per-shard stride in blocks.
    stride_shift: u32,
    /// `stride - 1` as u64 (for local-index extraction).
    stride_mask: u64,
    /// Exact division by `block_size`: `block_size = odd << div_shift`,
    /// `div_inv = odd⁻¹ mod 2⁶⁴` (see `raw.rs` §Perf).
    div_shift: u32,
    div_inv: u64,
    /// Traversal epoch: even = running, odd = pinned. While pinned, every
    /// alloc/free/drain parks at the pool boundary so the free chains,
    /// stashes and magazines are stable for
    /// [`Self::pin_for_traversal`]'s guard lifetime.
    traverse_epoch: AtomicU32,
    /// Ops currently between [`Self::enter_op`] and their guard drop.
    /// The traversal pin rendezvouses on this reaching zero, which is
    /// what upgrades the epoch park from "probably drained" to a hard
    /// exclusion guarantee (stragglers that raced past the epoch flip
    /// are still registered here).
    in_flight: CachePadded<AtomicU32>,
}

// SAFETY: the region is exclusively owned; shards are `Sync` and all
// shared mutation goes through their atomics.
unsafe impl Send for ShardedPool {}
// SAFETY: every method takes `&self`; all shared mutation funnels
// through the shards' atomics and the atomic placement/counter state.
unsafe impl Sync for ShardedPool {}

impl ShardedPool {
    /// Word-aligned pool of `num_blocks` × `block_size`, sharded
    /// `default_shards()` ways with the default steal-aware placement.
    pub fn with_blocks(block_size: usize, num_blocks: u32) -> Self {
        Self::with_shards(block_size, num_blocks, default_shards())
    }

    /// As [`Self::with_blocks`] with an explicit shard count (rounded
    /// *down* to a power of two — never more shards than requested — and
    /// clamped so every shard owns at least one block).
    pub fn with_shards(block_size: usize, num_blocks: u32, shards: usize) -> Self {
        let layout =
            Layout::from_size_align(block_size.max(1), core::mem::size_of::<usize>())
                .expect("bad layout");
        Self::with_layout(layout, num_blocks, shards)
    }

    /// As [`Self::with_shards`] with an explicit topology policy.
    pub fn with_placement(
        block_size: usize,
        num_blocks: u32,
        shards: usize,
        placement: Arc<dyn ShardPlacement>,
    ) -> Self {
        let layout =
            Layout::from_size_align(block_size.max(1), core::mem::size_of::<usize>())
                .expect("bad layout");
        Self::with_layout_placement(layout, num_blocks, shards, placement)
    }

    /// Explicit layout, default steal-aware placement: blocks honour
    /// `layout`'s alignment (stride rounded up to a multiple of it,
    /// region allocated at it).
    pub fn with_layout(layout: Layout, num_blocks: u32, shards: usize) -> Self {
        Self::with_layout_placement(
            layout,
            num_blocks,
            shards,
            Arc::new(StealAware::default()),
        )
    }

    /// Fully explicit constructor: layout, shard count and topology
    /// policy.
    pub fn with_layout_placement(
        layout: Layout,
        num_blocks: u32,
        shards: usize,
        placement: Arc<dyn ShardPlacement>,
    ) -> Self {
        assert!(num_blocks > 0, "pool must have at least one block");
        assert!(shards > 0, "need at least one shard");
        let align = layout.align().max(core::mem::size_of::<usize>());
        let bs = align_up(layout.size().max(MIN_BLOCK_SIZE), align);

        // Power-of-two shard count: never more shards than requested (or
        // than there are blocks), so round DOWN to a power of two.
        let want = shards.min(num_blocks as usize).max(1);
        let n_shards = if want.is_power_of_two() { want } else { next_pow2(want) / 2 };

        // Even split: the first `rem` shards take one extra block.
        let base = num_blocks / n_shards as u32;
        let rem = (num_blocks % n_shards as u32) as usize;
        // Uniform power-of-two stride ≥ the largest shard's count, so the
        // owning shard falls out of a block index with one shift.
        let stride = next_pow2((base + (rem > 0) as u32) as usize);
        let stride_shift = stride.trailing_zeros();

        let shard_bytes = bs.checked_mul(stride).expect("pool region size overflows usize");
        let total_bytes = shard_bytes
            .checked_mul(n_shards)
            .expect("pool region size overflows usize");
        let region_layout = Layout::from_size_align(total_bytes, align).expect("bad layout");
        // Zeroed so every byte of the region is initialised memory:
        // blocks are still handed out with no per-allocation init (the
        // paper's contract), but traversal snapshots may copy the payload
        // of a block its owner never wrote, and that read must be over
        // defined bytes. Fresh pages are zero from the OS anyway, so this
        // costs nothing beyond what first-touch would pay.
        // SAFETY: `region_layout` has non-zero, overflow-checked size.
        let region = NonNull::new(unsafe { std::alloc::alloc_zeroed(region_layout) })
            .expect("pool region allocation failed");

        let mut pools = Vec::with_capacity(n_shards);
        let mut counters = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let count = base + ((i < rem) as u32);
            // SAFETY: shard i's window [i*shard_bytes, i*shard_bytes +
            // count*bs) lies inside the region we just allocated; windows
            // are disjoint and each shard gets exclusive use of its own.
            let shard_raw = unsafe { region.as_ptr().add(i * shard_bytes) };
            // SAFETY: in-bounds pointer into a live allocation, never null.
            let shard_base = unsafe { NonNull::new_unchecked(shard_raw) };
            // SAFETY: `shard_base` addresses `count` blocks of `bs` bytes that
            // this pool owns and keeps alive for the shard's whole lifetime.
            pools.push(CachePadded::new(unsafe {
                AtomicPool::over_region(shard_base, bs, count)
            }));
            counters.push(ShardCounters::new());
        }

        // Grid index space (shard << stride_shift | local) must fit u32
        // with GRID_NIL free — guaranteed well before the region-bytes
        // overflow check would fire, but assert the invariant anyway.
        let grid_slots = (n_shards as u64) << stride_shift;
        assert!(grid_slots < GRID_NIL as u64, "grid index space overflows u32");
        let mut steal_next = Vec::with_capacity(grid_slots as usize);
        steal_next.resize_with(grid_slots as usize, || AtomicU32::new(GRID_NIL));

        // Home map starts unbound: the first use of a slot (under its
        // current generation) rebinds it from the placement policy.
        let mut home_map = Vec::with_capacity(MAX_HOME_SLOTS);
        home_map.resize_with(MAX_HOME_SLOTS, GenEntry::unbound);
        let mut win_steals = Vec::with_capacity(n_shards * n_shards);
        win_steals.resize_with(n_shards * n_shards, || AtomicU32::new(0));

        let window = placement.window();
        let div_shift = bs.trailing_zeros();
        let div_inv = mod_inverse_u64((bs >> div_shift) as u64);
        Self {
            shards: pools.into_boxed_slice(),
            counters: counters.into_boxed_slice(),
            steal_next: steal_next.into_boxed_slice(),
            placement,
            window,
            home_map: home_map.into_boxed_slice(),
            win_steals: win_steals.into_boxed_slice(),
            mem_start: region,
            layout: region_layout,
            block_size: bs,
            num_blocks,
            shard_mask: n_shards - 1,
            stride_shift,
            stride_mask: stride as u64 - 1,
            div_shift,
            div_inv,
            traverse_epoch: AtomicU32::new(0),
            in_flight: CachePadded::new(AtomicU32::new(0)),
        }
    }

    /// Entry point of every alloc/free/drain (magazine layer included):
    /// registers the op in [`Self::in_flight`], then parks if a
    /// traversal pin is (or lands) in place. The returned guard keeps
    /// the registration until the op's last chain touch, which is what
    /// lets [`Self::pin_for_traversal`] rendezvous on a *provable*
    /// quiescent point instead of a grace window.
    ///
    /// SeqCst on both sides of the store→load pairs (`in_flight` inc vs
    /// epoch read here; epoch flip vs `in_flight` read in the pin) puts
    /// the four accesses in one total order, so exactly one of two
    /// things happens: this op's registration is visible to the pinner's
    /// rendezvous loop (which then waits for the guard drop), or this op
    /// sees the odd epoch and backs out before touching any chain.
    ///
    /// Inner layers must NOT re-enter (the `*_impl` variants exist for
    /// that): a nested entry would park against a pin that is itself
    /// waiting for the outer registration to drop.
    #[inline(always)]
    pub(crate) fn enter_op(&self) -> OpGuard<'_> {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        if self.traverse_epoch.load(Ordering::SeqCst) & 1 != 0 {
            self.enter_op_parked();
        }
        OpGuard { pool: self }
    }

    /// Slow path of [`Self::enter_op`]: deregister (so the pinner's
    /// rendezvous can complete), wait the pin out, re-register.
    #[cold]
    fn enter_op_parked(&self) {
        loop {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            while self.traverse_epoch.load(Ordering::Acquire) & 1 != 0 {
                std::thread::yield_now();
            }
            self.in_flight.fetch_add(1, Ordering::SeqCst);
            if self.traverse_epoch.load(Ordering::SeqCst) & 1 == 0 {
                return;
            }
        }
    }

    /// Pin the pool for traversal: bumps the traversal epoch to odd, so
    /// every allocate/deallocate/drain (magazine fast paths included, via
    /// the magazine layer's own [`Self::enter_op`] call) parks at the
    /// pool boundary until the returned guard drops — then rendezvouses
    /// with ops already in flight by spinning until the [`Self::enter_op`]
    /// registration count reaches zero. On return, no thread is anywhere
    /// between an entry point and its last chain touch: the chains,
    /// stashes and magazine contents are exactly stable, not just
    /// probably so.
    ///
    /// The pinning thread itself MUST NOT allocate or free on this pool
    /// while the guard lives — it would park against its own pin.
    /// Concurrent pinners serialise (second pin waits for the first).
    pub fn pin_for_traversal(&self) -> TraversalPin<'_> {
        loop {
            let e = self.traverse_epoch.load(Ordering::Relaxed);
            if e & 1 == 0
                && self
                    .traverse_epoch
                    .compare_exchange(e, e + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok()
            {
                break;
            }
            std::thread::yield_now();
        }
        // Rendezvous: every op that entered before the flip is still
        // registered; ops entering after it see the odd epoch and back
        // out (see `enter_op` for the ordering argument). Zero here
        // therefore proves no op is past an entry point.
        while self.in_flight.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
        TraversalPin { pool: self }
    }

    /// Is a traversal pin currently held? (Tests / diagnostics.)
    pub fn traversal_pinned(&self) -> bool {
        self.traverse_epoch.load(Ordering::Relaxed) & 1 != 0
    }

    /// Pointer for a grid index (shard << stride_shift | local). Shared
    /// with the magazine layer, which caches grid indices and converts on
    /// the way out — one multiply+add, no atomics.
    #[inline(always)]
    pub(crate) fn grid_to_ptr(&self, grid: u32) -> NonNull<u8> {
        // SAFETY: grid indices come from shard geometry; the offset lies
        // inside the owned region.
        let p = unsafe { self.mem_start.as_ptr().add(grid as usize * self.block_size) };
        // SAFETY: in-bounds pointer into a live allocation, never null.
        unsafe { NonNull::new_unchecked(p) }
    }

    /// Grid index for a block pointer of this pool — the §Perf exact
    /// division (shift + multiplicative inverse, no hardware divide).
    /// Inverse of [`Self::grid_to_ptr`]; `p` must be a block of this
    /// pool.
    #[inline(always)]
    pub(crate) fn ptr_to_grid(&self, p: NonNull<u8>) -> u32 {
        debug_assert!(self.contains(p), "ptr_to_grid: {p:p} is not a block of this pool");
        let off = (p.as_ptr() as usize - self.mem_start.as_ptr() as usize) as u64;
        ((off >> self.div_shift).wrapping_mul(self.div_inv)) as u32
    }

    /// Effective home shard for `(slot, gen)` from [`home_slot`].
    #[inline]
    fn resolve_home(&self, slot: u32, gen: u32) -> usize {
        let n = self.shards.len();
        if slot & SLOT_SHARED_BIT != 0 {
            // Shared slot: stateless placement, no rehome participation.
            return self.placement.place((slot & !SLOT_SHARED_BIT) as usize, n) % n;
        }
        let idx = slot as usize & (MAX_HOME_SLOTS - 1);
        match self.home_map[idx].resolve(gen, n) {
            Some(target) => target,
            None => self.rebind_home(idx, slot, gen),
        }
    }

    /// First use of a slot generation in this pool (or a recycled slot's
    /// stale entry): bind it from the placement policy.
    #[cold]
    fn rebind_home(&self, idx: usize, slot: u32, gen: u32) -> usize {
        let n = self.shards.len();
        let target = self.placement.place(slot as usize, n) % n;
        self.home_map[idx].rebind(target, gen);
        target
    }

    /// The calling thread's current effective home shard (tests, benches).
    pub fn current_home(&self) -> usize {
        let (slot, gen) = home_slot();
        self.resolve_home(slot, gen)
    }

    /// The active topology policy.
    pub fn placement_name(&self) -> &'static str {
        self.placement.name()
    }

    /// Record one successful allocation at effective home `home`, served
    /// by shard `victim` (`victim == home` for a local hit), and close
    /// the rehome window when it fills.
    #[inline]
    fn note_window(&self, slot: u32, gen: u32, home: usize, victim: usize) {
        if self.window == 0 || slot & SLOT_SHARED_BIT != 0 {
            return;
        }
        let n = self.shards.len();
        if n == 1 {
            return;
        }
        if victim != home {
            self.win_steals[home * n + victim].fetch_add(1, Ordering::Relaxed);
        }
        let c = &self.counters[home];
        let w = c.win_ops.fetch_add(1, Ordering::Relaxed) + 1;
        if w >= self.window {
            c.win_ops.store(0, Ordering::Relaxed);
            self.consider_rehome(slot, gen, home);
        }
    }

    /// A window closed at `home`: snapshot-and-reset its victim profile
    /// and let the placement policy move the deciding thread. The window
    /// counters are shared by every thread homed here, so the profile is
    /// an approximation — good enough for a heuristic, and each thread
    /// only ever moves itself (single generation-stamped CAS on its own
    /// home-map entry), so the switch is race-free.
    #[cold]
    fn consider_rehome(&self, slot: u32, gen: u32, home: usize) {
        let n = self.shards.len();
        let mut steals_total = 0u32;
        let mut victim = home;
        let mut victim_steals = 0u32;
        for (v, cell) in self.win_steals[home * n..home * n + n].iter().enumerate() {
            let x = cell.swap(0, Ordering::Relaxed);
            steals_total = steals_total.saturating_add(x);
            if x > victim_steals {
                victim_steals = x;
                victim = v;
            }
        }
        let local = self.window.saturating_sub(steals_total);
        if let Some(target) =
            self.placement.rehome(home, local, steals_total, victim, victim_steals)
        {
            let target = target % n;
            if target == home {
                return;
            }
            let idx = slot as usize & (MAX_HOME_SLOTS - 1);
            if self.home_map[idx].swing(home, target, gen) {
                self.counters[home].rehomes.fetch_add(1, Ordering::Relaxed);
                // Leave nothing stranded behind: park-ed extras of the
                // abandoned home go back to their owning shards.
                self.drain_slot_stash(home);
            }
        }
    }

    /// Pop one grid index off `slot`'s steal stash (Treiber, tag-guarded
    /// — `proto::stash`'s counted pop machine over `steal_next`).
    fn stash_pop(&self, slot: usize) -> Option<u32> {
        self.counters[slot].stash.pop(&self.steal_next)
    }

    /// Park a pre-linked chain of grid indices in `slot`'s stash with one
    /// head CAS per attempt (the counted chain-push machine).
    fn stash_push_chain(&self, slot: usize, grids: &[u32]) {
        debug_assert!(!grids.is_empty());
        self.counters[slot].stash.push_chain(&self.steal_next, grids);
    }

    /// Drain home slot `home`'s steal stash, returning every parked block
    /// to its *owning* shard's free list. Safe to call from any thread at
    /// any time (the stash is a lock-free stack; blocks conserve).
    fn drain_slot_stash(&self, home: usize) -> u32 {
        let mut drained = 0u32;
        while let Some(grid) = self.stash_pop(home) {
            let shard = (grid >> self.stride_shift) as usize;
            let local = (grid as u64 & self.stride_mask) as u32;
            self.shards[shard].deallocate_index(local);
            drained += 1;
        }
        if drained > 0 {
            self.counters[home].stash_drained.fetch_add(drained as u64, Ordering::Relaxed);
        }
        drained
    }

    /// Return every stash-parked block to its owning shard's free list;
    /// returns the number of blocks moved. Orphan reclamation for thread
    /// churn: stash chains left by exited threads stay *reachable* via
    /// the allocate slow path regardless, but draining puts them back on
    /// the local fast paths. The serving engine calls this from its
    /// periodic maintenance tick.
    pub fn drain_stashes(&self) -> u32 {
        let _op = self.enter_op();
        (0..self.counters.len()).map(|i| self.drain_slot_stash(i)).sum()
    }

    /// Lock-free allocate: home shard, then the home steal stash, then a
    /// batched steal round the sibling ring, then sibling stashes.
    /// `None` only when every shard and stash is (momentarily) empty.
    #[inline]
    pub fn allocate(&self) -> Option<NonNull<u8>> {
        let _op = self.enter_op();
        self.allocate_impl()
    }

    /// [`Self::allocate`] minus the traversal-park entry — for callers
    /// (the magazine layer) already holding an [`OpGuard`].
    #[inline]
    pub(crate) fn allocate_impl(&self) -> Option<NonNull<u8>> {
        let (slot, gen) = home_slot();
        let home = self.resolve_home(slot, gen);
        let c = &self.counters[home];
        if let Some(p) = self.shards[home].allocate() {
            c.local_hits.fetch_add(1, Ordering::Relaxed);
            // Local supply is back: decay the steal batch.
            let k = c.steal_batch.load(Ordering::Relaxed);
            if k > 1 {
                c.steal_batch.store(k / 2, Ordering::Relaxed);
            }
            self.note_window(slot, gen, home, home);
            return Some(p);
        }
        // Batch extras imported by an earlier steal scan.
        if let Some(grid) = self.stash_pop(home) {
            c.stash_hits.fetch_add(1, Ordering::Relaxed);
            self.note_window(slot, gen, home, (grid >> self.stride_shift) as usize);
            return Some(self.grid_to_ptr(grid));
        }
        // Local shard dry: steal from siblings so capacity is pooled, not
        // partitioned. The scan order (home+1, home+2, …) spreads victim
        // pressure instead of dog-piling shard 0. Take up to k blocks per
        // scan — one for the caller, the rest into the home stash — so a
        // steady cross-shard regime pays one scan per k allocations.
        let k = c.steal_batch.load(Ordering::Relaxed).clamp(1, MAX_STEAL_BATCH);
        let mut buf = [0u32; MAX_STEAL_BATCH as usize];
        for j in 1..=self.shard_mask {
            let s = (home + j) & self.shard_mask;
            let got = self.shards[s].allocate_batch(k, &mut buf);
            if got > 0 {
                c.steals.fetch_add(got as u64, Ordering::Relaxed);
                c.steal_scans.fetch_add(1, Ordering::Relaxed);
                // Ramp the batch: recent steals predict more steals.
                c.steal_batch.store((k * 2).min(MAX_STEAL_BATCH), Ordering::Relaxed);
                let base = (s as u32) << self.stride_shift;
                for g in buf[..got as usize].iter_mut() {
                    *g += base;
                }
                if got > 1 {
                    self.stash_push_chain(home, &buf[1..got as usize]);
                }
                self.note_window(slot, gen, home, s);
                return Some(self.grid_to_ptr(buf[0]));
            }
        }
        // Last resort: raid every stash, own included (a racing thread
        // may have parked extras in any of them during our scan). This is
        // also what keeps orphaned stash chains from exited threads
        // reachable without any drain having run.
        for j in 0..=self.shard_mask {
            let s = (home + j) & self.shard_mask;
            if let Some(grid) = self.stash_pop(s) {
                c.stash_hits.fetch_add(1, Ordering::Relaxed);
                self.note_window(slot, gen, home, (grid >> self.stride_shift) as usize);
                return Some(self.grid_to_ptr(grid));
            }
        }
        c.failures.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Bulk allocate for the magazine layer: detach up to `want` blocks
    /// from the *home* shard's free list in one chain CAS (plus a
    /// watermark top-up), writing their **grid indices** into `out` and
    /// returning the count. Returns 0 when the home shard is dry — the
    /// caller falls back to [`Self::allocate`], whose steal scan already
    /// batch-amortises cross-shard traffic.
    ///
    /// Counts the whole batch as home local hits, but charges the rehome
    /// window only **once**: a magazine refill is one routing decision,
    /// so the `StealAware` policy sees refills, not individual blocks,
    /// and its window thresholds keep their meaning under caching.
    // NOTE: the bulk grid paths deliberately do NOT register with
    // `enter_op`: they run inside a magazine-layer op that already holds
    // an `OpGuard` (bind, flush, stale-rescue), and a nested entry would
    // park against a pin waiting for the outer registration — stranding
    // a magazine slot in CLAIMED for the pin's lifetime, which the
    // pinned traversal spins on. The rendezvous happens at the layer
    // entry points instead.
    pub(crate) fn allocate_grids(&self, want: u32, out: &mut [u32]) -> u32 {
        debug_assert!(want as usize <= out.len());
        let (slot, gen) = home_slot();
        let home = self.resolve_home(slot, gen);
        let got = self.shards[home].allocate_batch(want, out);
        if got == 0 {
            return 0;
        }
        let c = &self.counters[home];
        c.local_hits.fetch_add(got as u64, Ordering::Relaxed);
        // Local supply: decay the steal batch exactly like a local hit.
        let k = c.steal_batch.load(Ordering::Relaxed);
        if k > 1 {
            c.steal_batch.store(k / 2, Ordering::Relaxed);
        }
        let base = (home as u32) << self.stride_shift;
        for g in out[..got as usize].iter_mut() {
            *g += base;
        }
        self.note_window(slot, gen, home, home);
        got
    }

    /// Bulk deallocate for the magazine layer: return a set of grid
    /// indices to their owning shards, one pre-linked chain and **one**
    /// head CAS per shard touched (via
    /// [`AtomicPool::deallocate_indices`]) instead of one CAS per block.
    /// Sorting groups the grids by shard (shard = grid >> stride_shift),
    /// which is also why the slice is taken `&mut`.
    pub(crate) fn deallocate_grids(&self, grids: &mut [u32]) {
        if grids.is_empty() {
            return;
        }
        grids.sort_unstable();
        let mut i = 0;
        while i < grids.len() {
            let shard = (grids[i] >> self.stride_shift) as usize;
            let mut j = i + 1;
            while j < grids.len() && (grids[j] >> self.stride_shift) as usize == shard {
                j += 1;
            }
            for g in grids[i..j].iter_mut() {
                *g = (*g as u64 & self.stride_mask) as u32;
            }
            self.shards[shard].deallocate_indices(&grids[i..j]);
            self.counters[shard].frees.fetch_add((j - i) as u64, Ordering::Relaxed);
            i = j;
        }
    }

    /// Lock-free deallocate. O(1): the owning shard is decoded from the
    /// pointer offset with shift + multiplicative-inverse exact division —
    /// no hardware divide, no shard id stored in the block.
    ///
    /// # Safety
    /// `p` must come from `allocate` on this pool, freed at most once.
    #[inline]
    pub unsafe fn deallocate(&self, p: NonNull<u8>) {
        let _op = self.enter_op();
        // SAFETY: forwarded contract.
        unsafe { self.deallocate_impl(p) }
    }

    /// [`Self::deallocate`] minus the traversal-park entry — for callers
    /// (the magazine layer) already holding an [`OpGuard`].
    ///
    /// # Safety
    /// As [`Self::deallocate`].
    #[inline]
    pub(crate) unsafe fn deallocate_impl(&self, p: NonNull<u8>) {
        let grid = self.ptr_to_grid(p);
        let shard = (grid >> self.stride_shift) as usize;
        let local = (grid as u64 & self.stride_mask) as u32;
        self.shards[shard].deallocate_index(local);
        self.counters[shard].frees.fetch_add(1, Ordering::Relaxed);
    }

    /// Fast ownership test: is `p` inside this pool's region? Range
    /// compare only (no divide) — sufficient for allocator routing
    /// because no other allocator can produce a pointer inside a region
    /// this pool exclusively owns. Use [`Self::contains`] when the
    /// pointer must also be validated as an actual block address.
    #[inline]
    pub fn owns(&self, p: NonNull<u8>) -> bool {
        let start = self.mem_start.as_ptr() as usize;
        let a = p.as_ptr() as usize;
        a >= start && a < start + self.layout.size()
    }

    /// Is `p` a plausible block of this pool (in range, on the block grid,
    /// inside a shard's populated window)?
    pub fn contains(&self, p: NonNull<u8>) -> bool {
        let start = self.mem_start.as_ptr() as usize;
        let a = p.as_ptr() as usize;
        if a < start || a >= start + self.layout.size() {
            return false;
        }
        let off = (a - start) as u64;
        if off % self.block_size as u64 != 0 {
            return false;
        }
        let grid = off / self.block_size as u64;
        let shard = (grid >> self.stride_shift) as usize;
        let local = grid & self.stride_mask;
        shard < self.shards.len() && local < self.shards[shard].num_blocks() as u64
    }

    // ---- introspection ----------------------------------------------------

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total usable blocks (excludes stride padding).
    pub fn num_blocks(&self) -> u32 {
        self.num_blocks
    }

    /// Effective (aligned) block size.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Free blocks summed over shards plus blocks parked in steal
    /// stashes (exact when quiescent).
    pub fn num_free(&self) -> u32 {
        self.shards.iter().map(|s| s.num_free()).sum::<u32>()
            + self.counters.iter().map(|c| c.stash.count()).sum::<u32>()
    }

    pub fn region_start(&self) -> usize {
        self.mem_start.as_ptr() as usize
    }

    /// Full mapped region length in bytes, *including* stride padding —
    /// the half-open range `[region_start, region_start + region_bytes)`
    /// contains every pointer this pool can hand out (it is exactly the
    /// range [`Self::owns`] tests). Address-sorted tables of these
    /// ranges drive the multi-pool tier's O(log C) pointer→class
    /// resolution.
    pub fn region_bytes(&self) -> usize {
        self.layout.size()
    }

    /// Usable capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.block_size * self.num_blocks as usize
    }

    /// Virtual address-space slack from stride padding (never touched, so
    /// not resident on demand-paged systems).
    pub fn padded_bytes(&self) -> usize {
        self.layout.size() - self.capacity_bytes()
    }

    /// Concurrency tax: shard headers + side tables + counters + the
    /// batched-steal stash links + the home map and rehome window matrix.
    pub fn overhead_bytes(&self) -> usize {
        core::mem::size_of::<Self>()
            + self.shards.iter().map(|s| s.overhead_bytes()).sum::<usize>()
            + self.counters.len() * core::mem::size_of::<ShardCounters>()
            + self.steal_next.len() * 4
            + self.home_map.len() * 8
            + self.win_steals.len() * 4
    }

    /// Snapshot of per-shard hit/steal/rehome accounting.
    pub fn stats(&self) -> ShardedPoolStats {
        let per_shard = self
            .shards
            .iter()
            .zip(self.counters.iter())
            .map(|(s, c)| ShardStats {
                num_blocks: s.num_blocks(),
                num_free: s.num_free(),
                local_hits: c.local_hits.load(Ordering::Relaxed),
                steals: c.steals.load(Ordering::Relaxed),
                steal_scans: c.steal_scans.load(Ordering::Relaxed),
                stash_hits: c.stash_hits.load(Ordering::Relaxed),
                stash_free: c.stash.count(),
                failed_allocs: c.failures.load(Ordering::Relaxed),
                frees: c.frees.load(Ordering::Relaxed),
                rehomes: c.rehomes.load(Ordering::Relaxed),
                stash_drained: c.stash_drained.load(Ordering::Relaxed),
            })
            .collect();
        ShardedPoolStats {
            block_size: self.block_size,
            num_blocks: self.num_blocks,
            per_shard,
            // The bare sharded pool has no per-thread cache; the magazine
            // layer overwrites this in `MagazinePool::stats`.
            magazines: MagazineStats::default(),
        }
    }

    /// Publish per-shard gauges into a [`Metrics`] registry under
    /// `prefix` (e.g. `pool.packets.shard0.steals`). Returns the snapshot
    /// the gauges were read from so callers aggregating across pools
    /// (e.g. `ShardedMultiPool`) do not snapshot twice.
    pub fn export_metrics(&self, metrics: &Metrics, prefix: &str) -> ShardedPoolStats {
        let s = self.stats();
        metrics.gauge(&format!("{prefix}.shards")).set(s.per_shard.len() as i64);
        metrics.gauge(&format!("{prefix}.free_blocks")).set(s.num_free() as i64);
        metrics
            .gauge(&format!("{prefix}.steals_total"))
            .set(s.total_steals() as i64);
        metrics
            .gauge(&format!("{prefix}.steal_scans_total"))
            .set(s.total_steal_scans() as i64);
        metrics
            .gauge(&format!("{prefix}.stash_hits_total"))
            .set(s.total_stash_hits() as i64);
        metrics
            .gauge(&format!("{prefix}.stash_blocks"))
            .set(s.total_stash_free() as i64);
        metrics
            .gauge(&format!("{prefix}.rehomes_total"))
            .set(s.total_rehomes() as i64);
        metrics
            .gauge(&format!("{prefix}.stash_drained_total"))
            .set(s.total_stash_drained() as i64);
        metrics
            .gauge(&format!("{prefix}.local_hit_pct"))
            .set((s.local_hit_rate() * 100.0) as i64);
        for (i, sh) in s.per_shard.iter().enumerate() {
            metrics
                .gauge(&format!("{prefix}.shard{i}.local_hits"))
                .set(sh.local_hits as i64);
            metrics.gauge(&format!("{prefix}.shard{i}.steals")).set(sh.steals as i64);
            metrics.gauge(&format!("{prefix}.shard{i}.free")).set(sh.num_free as i64);
        }
        s
    }
}

/// RAII guard for a traversal pin (see
/// [`ShardedPool::pin_for_traversal`]). While it lives, alloc/free on
/// the pinned pool park; dropping it releases the epoch.
pub struct TraversalPin<'a> {
    pool: &'a ShardedPool,
}

impl Drop for TraversalPin<'_> {
    fn drop(&mut self) {
        // Odd → even: release the parked ops.
        self.pool.traverse_epoch.fetch_add(1, Ordering::Release);
    }
}

/// RAII registration of one in-flight alloc/free/drain (see
/// [`ShardedPool::enter_op`]). Dropping it is the op's commit point for
/// the traversal rendezvous: after the drop, a pinner may start walking
/// chains this op touched.
pub(crate) struct OpGuard<'a> {
    pool: &'a ShardedPool,
}

impl Drop for OpGuard<'_> {
    fn drop(&mut self) {
        // Release publishes this op's chain writes to the pinner's
        // Acquire-or-stronger rendezvous read of the zero count.
        self.pool.in_flight.fetch_sub(1, Ordering::Release);
    }
}

/// Free = every shard's Treiber chain + watermark tail, every home
/// slot's steal-stash chain (stashed blocks are free capacity parked in
/// a different container), and the stride-padding slots that exist only
/// as address-space slack. Live = the grid complement. Exact at
/// quiescence or under [`ShardedPool::pin_for_traversal`].
impl super::traverse::Traverse for ShardedPool {
    fn grid_len(&self) -> usize {
        self.shards.len() << self.stride_shift
    }

    fn mark_free(&self, mask: &mut super::traverse::FreeMask) {
        let stride = 1u32 << self.stride_shift;
        for (si, shard) in self.shards.iter().enumerate() {
            let base = (si as u32) << self.stride_shift;
            shard.mark_free_indices(|local| mask.mark(base + local));
            // Stride padding past the shard's populated window: address
            // space, never blocks.
            for local in shard.num_blocks()..stride {
                mask.mark(base + local);
            }
        }
        // Steal stashes chain grid indices through `steal_next`. The walk
        // is bounded by the grid size and every link is range-checked, so
        // a torn read cannot loop or index out of bounds.
        let grid_slots = self.steal_next.len() as u32;
        for c in self.counters.iter() {
            let mut cur = c.stash.top();
            let mut steps = 0u32;
            while cur < grid_slots && steps < grid_slots {
                mask.mark(cur);
                cur = self.steal_next[cur as usize].load(Ordering::Acquire);
                steps += 1;
            }
        }
    }

    fn live_block(&self, index: u32) -> super::traverse::LiveBlock {
        super::traverse::LiveBlock {
            index,
            ptr: self.grid_to_ptr(index),
            size: self.block_size,
            class: 0,
        }
    }
}

impl Drop for ShardedPool {
    fn drop(&mut self) {
        // `&mut self` guarantees quiescence — no allocate/free/drain can
        // be in flight — so the steal-conservation identity must hold
        // exactly here. Every pool teardown in every debug build audits
        // the merged counters for free.
        #[cfg(debug_assertions)]
        self.stats().debug_assert_steal_conservation();
        // SAFETY: shards are `over_region` borrowers; only the striped
        // region is owned here, allocated in `with_shards` with this layout.
        unsafe { std::alloc::dealloc(self.mem_start.as_ptr(), self.layout) };
    }
}

impl std::fmt::Debug for ShardedPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedPool")
            .field("shards", &self.num_shards())
            .field("placement", &self.placement_name())
            .field("block_size", &self.block_size)
            .field("num_blocks", &self.num_blocks)
            .field("num_free", &self.num_free())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::placement::{Pinned, RoundRobin};
    use std::collections::BTreeSet;
    use std::sync::Arc;

    #[test]
    fn geometry_counts_sum_and_clamp() {
        // 10 blocks over a requested 5 shards → 4 shards, counts 3,3,2,2.
        let p = ShardedPool::with_shards(24, 10, 5);
        assert_eq!(p.num_shards(), 4);
        assert_eq!(p.num_blocks(), 10);
        assert_eq!(p.num_free(), 10);
        // One block, absurd shard request → one shard.
        let q = ShardedPool::with_shards(16, 1, 64);
        assert_eq!(q.num_shards(), 1);
        assert_eq!(q.num_free(), 1);
    }

    #[test]
    fn single_thread_can_drain_whole_pool() {
        // Capacity is pooled, not partitioned: one thread steals through
        // every sibling shard.
        let p = ShardedPool::with_shards(16, 64, 8);
        let mut seen = BTreeSet::new();
        for _ in 0..64 {
            let a = p.allocate().expect("stealing must reach all shards");
            assert!(seen.insert(a.as_ptr() as usize), "double handout");
            assert!(p.contains(a));
        }
        assert!(p.allocate().is_none());
        assert_eq!(p.num_free(), 0);
        let s = p.stats();
        assert_eq!(s.total_allocs(), 64);
        assert!(s.total_steals() > 0, "draining 8 shards must steal");
    }

    #[test]
    fn dealloc_routes_to_owning_shard() {
        let p = ShardedPool::with_shards(24, 10, 4); // stride 4, counts 3,3,2,2
        let ptrs: Vec<_> = (0..10).map(|_| p.allocate().unwrap()).collect();
        assert_eq!(p.num_free(), 0);
        for ptr in &ptrs {
            // SAFETY: every pointer came from `allocate` and is freed exactly once.
            unsafe { p.deallocate(*ptr) };
        }
        assert_eq!(p.num_free(), 10, "every block must return to its shard");
        // And the pool is fully reusable.
        let again: BTreeSet<usize> =
            (0..10).map(|_| p.allocate().unwrap().as_ptr() as usize).collect();
        assert_eq!(again.len(), 10);
        assert!(p.allocate().is_none());
    }

    #[test]
    fn odd_block_sizes_decode_correctly() {
        // Exercise the shift+inverse exact division on non-power-of-two
        // strides in bytes (block sizes get word-aligned: 24, 40, 72, 104).
        for bs in [17usize, 33, 65, 100] {
            let p = ShardedPool::with_shards(bs, 13, 4);
            let ptrs: Vec<_> = (0..13).map(|_| p.allocate().unwrap()).collect();
            for ptr in ptrs.into_iter().rev() {
                // SAFETY: every pointer came from `allocate` and is freed exactly once.
                unsafe { p.deallocate(ptr) };
            }
            assert_eq!(p.num_free(), 13, "block_size {bs}");
        }
    }

    #[test]
    fn alignment_honoured_across_shards() {
        let layout = Layout::from_size_align(20, 64).unwrap();
        let p = ShardedPool::with_layout(layout, 32, 4);
        for _ in 0..32 {
            let a = p.allocate().unwrap();
            assert_eq!(a.as_ptr() as usize % 64, 0);
        }
    }

    #[test]
    fn contains_rejects_foreign_and_padding() {
        let p = ShardedPool::with_shards(16, 6, 4); // counts 2,2,1,1; stride 2
        let a = p.allocate().unwrap();
        assert!(p.contains(a));
        // Off-grid pointer inside the region.
        // SAFETY: `add(1)` stays inside block 0 of the region.
        let off_raw = unsafe { a.as_ptr().add(1) };
        // SAFETY: in-bounds pointer into a live allocation, never null.
        let off = unsafe { NonNull::new_unchecked(off_raw) };
        assert!(!p.contains(off));
        // Padding slot of shard 2 (local index 1 does not exist there).
        // SAFETY: the padding-slot address lies inside the owned region, so it
        // is non-null; it is only compared, never dereferenced.
        let pad = unsafe {
            NonNull::new_unchecked(
                (p.region_start() + (2 * 2 + 1) * p.block_size()) as *mut u8,
            )
        };
        assert!(!p.contains(pad));
        // Foreign pointer.
        let mut other = [0u8; 16];
        assert!(!p.contains(NonNull::new(other.as_mut_ptr()).unwrap()));
        // SAFETY: `a` came from `allocate` and is freed exactly once.
        unsafe { p.deallocate(a) };
    }

    #[test]
    fn stats_split_hits_and_steals() {
        let p = ShardedPool::with_shards(16, 8, 4); // 2 blocks per shard
        let mut held = Vec::new();
        for _ in 0..8 {
            held.push(p.allocate().unwrap());
        }
        assert!(p.allocate().is_none());
        let s = p.stats();
        assert_eq!(s.total_allocs(), 8);
        assert_eq!(s.total_local_hits(), 2, "home shard holds 2 blocks");
        assert_eq!(s.total_steals(), 6);
        assert_eq!(s.total_failed(), 1);
        assert!(s.steal_rate() > 0.7);
        for ptr in held {
            // SAFETY: every held pointer came from `allocate` and is freed exactly once.
            unsafe { p.deallocate(ptr) };
        }
        assert_eq!(p.stats().total_frees(), 8);
    }

    #[test]
    fn metrics_export_publishes_gauges() {
        let p = ShardedPool::with_shards(16, 8, 2);
        let a = p.allocate().unwrap();
        // SAFETY: `a` was just allocated from this pool and is freed once.
        unsafe { p.deallocate(a) };
        let m = Metrics::new();
        p.export_metrics(&m, "pool.test");
        let report = m.report();
        assert!(report.contains("pool.test.shards = 2"), "{report}");
        assert!(report.contains("pool.test.free_blocks = 8"), "{report}");
        assert!(report.contains("pool.test.rehomes_total = 0"), "{report}");
        assert!(report.contains("pool.test.local_hit_pct = 100"), "{report}");
    }

    #[test]
    fn overhead_and_padding_accounting() {
        // 12 blocks, 4 shards → 3 per shard, stride 4 → 4 padding blocks.
        let p = ShardedPool::with_shards(64, 12, 4);
        assert_eq!(p.padded_bytes(), 4 * p.block_size());
        // Side tables: 4 bytes per real block, plus headers/counters plus
        // the fixed-size home map (MAX_HOME_SLOTS × 8 B) and window matrix.
        assert!(p.overhead_bytes() >= 12 * 4 + MAX_HOME_SLOTS * 8);
        assert!(p.overhead_bytes() < 8192, "{}", p.overhead_bytes());
    }

    #[test]
    fn batched_steal_ramps_and_conserves() {
        // Draining 8 shards single-threaded ramps k: far fewer scans than
        // stolen blocks, extras served from the stash, nothing lost.
        let p = ShardedPool::with_shards(16, 64, 8);
        let mut seen = BTreeSet::new();
        for _ in 0..64 {
            let a = p.allocate().expect("batched stealing must reach all shards");
            assert!(seen.insert(a.as_ptr() as usize), "double handout");
        }
        assert!(p.allocate().is_none());
        let s = p.stats();
        assert_eq!(s.total_allocs(), 64);
        assert_eq!(s.total_steals(), 56, "all 7 sibling shards drained");
        assert!(
            s.total_steal_scans() < s.total_steals(),
            "batching must amortise: {} scans for {} blocks",
            s.total_steal_scans(),
            s.total_steals()
        );
        assert!(s.avg_steal_batch() > 2.0, "{}", s.avg_steal_batch());
        // Conservation: every stolen block was returned by a scan, served
        // from a stash, drained back to a shard, or is still parked.
        assert_eq!(
            s.total_steals(),
            s.total_steal_scans()
                + s.total_stash_hits()
                + s.total_stash_drained()
                + s.total_stash_free() as u64
        );
        assert_eq!(s.total_stash_free(), 0, "full drain leaves no stash");
    }

    #[test]
    fn stash_push_pop_lifo_chain() {
        let p = ShardedPool::with_shards(16, 16, 4);
        // Mechanics only: park grid indices in slot 0's stash and pop.
        p.stash_push_chain(0, &[8, 9, 10]);
        assert_eq!(p.counters[0].stash.count(), 3);
        assert_eq!(p.stash_pop(0), Some(8));
        assert_eq!(p.stash_pop(0), Some(9));
        assert_eq!(p.stash_pop(0), Some(10));
        assert_eq!(p.stash_pop(0), None);
        assert_eq!(p.counters[0].stash.count(), 0);
    }

    #[test]
    fn allocate_raids_sibling_stash() {
        // A block parked in a slot the caller is NOT homed on (a
        // home-mate's in-flight batch import) must still be reachable.
        let p = ShardedPool::with_shards(16, 8, 4);
        let held: Vec<_> = (0..8).map(|_| p.allocate().unwrap()).collect();
        assert!(p.allocate().is_none());
        let home = p.current_home();
        // Return the caller's first block (a home local hit), pull it back
        // out of the home shard and park it in a sibling slot's stash.
        // SAFETY: `held[0]` came from `allocate` and is freed exactly once here.
        unsafe { p.deallocate(held[0]) };
        let local = p.shards[home].allocate_index().expect("just freed");
        let grid = ((home as u32) << p.stride_shift) + local;
        p.stash_push_chain((home + 1) & p.shard_mask, &[grid]);
        assert_eq!(p.num_free(), 1, "stashed block counts as free");
        let got = p.allocate().expect("raid must reach the sibling stash");
        assert_eq!(got.as_ptr(), held[0].as_ptr());
        assert!(p.stats().total_stash_hits() >= 1);
        assert_eq!(p.num_free(), 0);
    }

    #[test]
    fn drain_stashes_returns_parked_blocks_to_owners() {
        let p = ShardedPool::with_shards(16, 8, 4);
        let held: Vec<_> = (0..8).map(|_| p.allocate().unwrap()).collect();
        let home = p.current_home();
        // SAFETY: `held[0]` came from `allocate` and is freed exactly once here.
        unsafe { p.deallocate(held[0]) };
        let local = p.shards[home].allocate_index().expect("just freed");
        let grid = ((home as u32) << p.stride_shift) + local;
        // Park it in a sibling's stash — the shape an exited thread's
        // orphaned batch import leaves behind.
        p.stash_push_chain((home + 1) & p.shard_mask, &[grid]);
        assert_eq!(p.stats().total_stash_free(), 1);
        assert_eq!(p.drain_stashes(), 1);
        let s = p.stats();
        assert_eq!(s.total_stash_free(), 0, "stash empty after drain");
        assert_eq!(s.total_stash_drained(), 1);
        assert_eq!(
            p.shards[home].num_free(),
            1,
            "drained block back on its owning shard's free list"
        );
        assert_eq!(p.drain_stashes(), 0, "idempotent when empty");
    }

    #[test]
    fn grid_roundtrip_and_bulk_grid_paths() {
        // ptr↔grid must invert exactly on odd block sizes (exact-division
        // decode), and the magazine-facing bulk paths must conserve.
        let p = ShardedPool::with_shards(24, 16, 4);
        let a = p.allocate().unwrap();
        let g = p.ptr_to_grid(a);
        assert_eq!(p.grid_to_ptr(g).as_ptr(), a.as_ptr());
        // SAFETY: `a` came from `allocate` and is freed exactly once.
        unsafe { p.deallocate(a) };

        // Bulk allocate from the caller's home shard only.
        let mut out = [0u32; 8];
        let got = p.allocate_grids(4, &mut out);
        assert!((1..=4).contains(&got), "home shard holds 4 blocks: {got}");
        let home = p.current_home();
        for &g in &out[..got as usize] {
            assert_eq!((g >> p.stride_shift) as usize, home, "grids are home-local");
            assert!(p.contains(p.grid_to_ptr(g)));
        }
        // Bulk free returns them as per-shard chains; counts stay exact.
        let frees_before = p.stats().total_frees();
        p.deallocate_grids(&mut out[..got as usize]);
        assert_eq!(p.stats().total_frees(), frees_before + got as u64);
        assert_eq!(p.num_free(), 16);
        // The whole pool still hands out every block exactly once.
        let mut seen = BTreeSet::new();
        while let Some(a) = p.allocate() {
            assert!(seen.insert(a.as_ptr() as usize));
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn deallocate_grids_groups_cross_shard_chains() {
        // Hand-build a mixed-shard grid set: deallocate_grids must route
        // every block to its owning shard (one chain per shard).
        let p = ShardedPool::with_shards(16, 16, 4); // stride 4
        let held: Vec<_> = (0..16).map(|_| p.allocate().unwrap()).collect();
        let mut grids: Vec<u32> = held.iter().map(|a| p.ptr_to_grid(*a)).collect();
        p.deallocate_grids(&mut grids);
        assert_eq!(p.num_free(), 16);
        for (i, s) in p.shards.iter().enumerate() {
            assert_eq!(s.num_free(), 4, "shard {i} must get its own blocks back");
        }
    }

    #[test]
    fn round_robin_placement_never_rehomes() {
        let placement = Arc::new(RoundRobin);
        let p = ShardedPool::with_placement(16, 64, 8, placement);
        assert_eq!(p.placement_name(), "round_robin");
        let home0 = p.current_home();
        // Hammer way past any window: a static placement never moves.
        for _ in 0..2_000 {
            let a = p.allocate().unwrap();
            // SAFETY: `a` was just allocated from this pool and is freed once.
            unsafe { p.deallocate(a) };
        }
        assert_eq!(p.current_home(), home0);
        assert_eq!(p.stats().total_rehomes(), 0);
    }

    #[test]
    fn steal_aware_rehomes_single_thread_to_its_victim() {
        use crate::pool::placement::StealAware;
        // Skewed start: this thread is pinned to shard 0, whose 8 blocks
        // we immediately pin down — every further allocation must steal.
        let placement = Arc::new(StealAware {
            window: 16,
            threshold_pct: 50,
            base: Arc::new(Pinned::all(0)),
        });
        let p = ShardedPool::with_placement(16, 32, 4, placement); // 8 blocks/shard
        assert_eq!(p.current_home(), 0);
        let held: Vec<_> = (0..8).map(|_| p.allocate().unwrap()).collect();
        assert_eq!(p.stats().total_local_hits(), 8);
        // Cross-shard churn: every pair steals from (or stash-hits blocks
        // of) a sibling, so the window fills with one dominant victim and
        // the policy moves us there.
        for _ in 0..64 {
            let a = p.allocate().expect("siblings have free blocks");
            // SAFETY: `a` was just allocated from this pool and is freed once.
            unsafe { p.deallocate(a) };
        }
        let s = p.stats();
        assert!(s.total_rehomes() >= 1, "sustained stealing must rehome: {s:?}");
        let new_home = p.current_home();
        assert_ne!(new_home, 0, "moved off the exhausted shard");
        // Post-rehome the fast path is local again.
        let local_before = p.stats().total_local_hits();
        for _ in 0..32 {
            let a = p.allocate().unwrap();
            // SAFETY: `a` was just allocated from this pool and is freed once.
            unsafe { p.deallocate(a) };
        }
        let local_after = p.stats().total_local_hits();
        assert!(
            local_after - local_before >= 30,
            "rehomed thread should hit locally: {} → {}",
            local_before,
            local_after
        );
        for ptr in held {
            // SAFETY: every held pointer came from `allocate` and is freed exactly once.
            unsafe { p.deallocate(ptr) };
        }
        assert_eq!(p.num_free(), 32);
        // Stolen-block conservation holds through the rehome drain.
        let s = p.stats();
        assert_eq!(
            s.total_steals(),
            s.total_steal_scans()
                + s.total_stash_hits()
                + s.total_stash_drained()
                + s.total_stash_free() as u64
        );
    }

    #[test]
    fn home_slots_recycle_across_thread_churn() {
        // Waves of short-lived threads must reuse slot ids instead of
        // growing the arena without bound. Other tests run concurrently in
        // this process, so assert with slack: 64 sequential threads must
        // not consume anywhere near 64 fresh ids.
        let before = home_slots_high_water();
        let epoch_before = home_slot_epoch();
        let pool = ShardedPool::with_shards(16, 32, 4);
        for _ in 0..16 {
            std::thread::scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {
                        let a = pool.allocate().unwrap();
                        // SAFETY: `a` was just allocated from this pool and is freed once.
                        unsafe { pool.deallocate(a) };
                    });
                }
            });
        }
        let after = home_slots_high_water();
        // The old monotone counter would have consumed ≥ 64 fresh ids for
        // these threads alone (other tests' concurrent threads only add).
        assert!(
            after - before < 64,
            "64 churned threads must recycle slots: {before} → {after}"
        );
        assert!(
            home_slot_epoch() >= epoch_before + 64,
            "every exit must bump the churn epoch"
        );
        assert_eq!(pool.num_free(), 32);
    }

    #[test]
    fn concurrent_churn_exact_at_quiescence() {
        let pool = Arc::new(ShardedPool::with_shards(32, 128, 4));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let mut rng = crate::util::Rng::new(t + 1);
                    let mut held = Vec::new();
                    for _ in 0..20_000 {
                        if held.is_empty() || rng.gen_bool(0.5) {
                            if let Some(p) = pool.allocate() {
                                held.push(p.as_ptr() as usize);
                            }
                        } else {
                            let i = rng.gen_usize(0, held.len());
                            let addr = held.swap_remove(i);
                            // SAFETY: `addr` came from `allocate`, so non-null.
                            let p = unsafe { NonNull::new_unchecked(addr as *mut u8) };
                            // SAFETY: removed from `held`: freed exactly once.
                            unsafe { pool.deallocate(p) };
                        }
                    }
                    for addr in held {
                        // SAFETY: `addr` came from `allocate`, so non-null.
                        let p = unsafe { NonNull::new_unchecked(addr as *mut u8) };
                        // SAFETY: never freed in the loop above.
                        unsafe { pool.deallocate(p) };
                    }
                });
            }
        });
        assert_eq!(pool.num_free(), 128);
        let s = pool.stats();
        assert_eq!(s.total_allocs(), s.total_frees());
    }
}
