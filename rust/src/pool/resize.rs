//! `ResizablePool` — §VII "Resizing" as a usable type.
//!
//! The paper: "if more memory blocks are needed than are available, and
//! further additional memory follows the end of the continuous memory
//! pool's allocation, the pool can be extended effortlessly with little
//! cost by updating its member variables."
//!
//! We realise "memory following the end" by *reserving* virtual capacity up
//! front (one region of `max_blocks`) and *committing* only `num_blocks` of
//! it to the pool. `grow()` bumps the committed count — O(1), no loops, no
//! copying, exactly the member-variable update the paper describes.
//! `shrink_to_watermark()` trims never-touched tail blocks (§VII ¶2).

use core::alloc::Layout;
use core::ptr::NonNull;

use super::raw::RawPool;
use crate::util::align::checked_align_up;

/// A pool that can grow up to a reserved maximum and shrink to its
/// lazy-initialisation watermark.
pub struct ResizablePool {
    raw: RawPool,
    max_blocks: u32,
    layout: Layout,
}

impl ResizablePool {
    /// Reserve `max_blocks` worth of address space, commit `initial_blocks`.
    pub fn new(block_size: usize, initial_blocks: u32, max_blocks: u32) -> Self {
        assert!(initial_blocks >= 1 && initial_blocks <= max_blocks);
        let align = core::mem::size_of::<usize>();
        // Checked align-up: a plain `align_up(usize::MAX, 8)` wraps to 0,
        // which would sail through the reservation check below and reach
        // `alloc` with a zero-size layout (UB). Unlike the Layout-taking
        // pool constructors (where `Layout::from_size_align` already
        // bounds the size), this constructor takes a raw usize.
        let bs = checked_align_up(block_size.max(4), align)
            .expect("pool block size overflows usize (alignment padding)");
        // The reservation is `bs * max_blocks` even though only
        // `initial_blocks` are committed — the product must be checked
        // exactly like `RawPool::new` checks its committed size, or an
        // adversarial `max_blocks` wraps to a tiny reservation that later
        // `grow` calls happily run off the end of.
        let bytes = bs
            .checked_mul(max_blocks as usize)
            .expect("pool reservation size overflows usize (block_size * max_blocks)");
        let layout = Layout::from_size_align(bytes, align).expect("bad layout");
        // SAFETY: `layout` has non-zero, overflow-checked size.
        let region = NonNull::new(unsafe { std::alloc::alloc(layout) })
            .expect("pool region allocation failed");
        // SAFETY: region is valid for max_blocks ≥ initial_blocks blocks.
        let raw = unsafe { RawPool::new(region, bytes, bs, initial_blocks) };
        Self { raw, max_blocks, layout }
    }

    #[inline]
    pub fn allocate(&mut self) -> Option<NonNull<u8>> {
        self.raw.allocate()
    }

    /// Allocate, growing (doubling, capped at `max_blocks`) on exhaustion.
    pub fn allocate_or_grow(&mut self) -> Option<NonNull<u8>> {
        if let Some(p) = self.raw.allocate() {
            return Some(p);
        }
        let cur = self.raw.num_blocks();
        if cur >= self.max_blocks {
            return None;
        }
        let target = doubling_target(cur, self.max_blocks);
        // SAFETY: the reserved region covers max_blocks.
        unsafe { self.raw.grow(target) };
        self.raw.allocate()
    }

    /// # Safety
    /// `p` must come from this pool's `allocate*`, freed at most once.
    #[inline]
    pub unsafe fn deallocate(&mut self, p: NonNull<u8>) {
        self.raw.deallocate(p)
    }

    /// Explicit O(1) grow to `new_blocks` (≤ reserved maximum).
    pub fn grow(&mut self, new_blocks: u32) {
        assert!(
            new_blocks <= self.max_blocks,
            "grow beyond reservation: {new_blocks} > {}",
            self.max_blocks
        );
        // SAFETY: within the reserved region.
        unsafe { self.raw.grow(new_blocks) };
    }

    /// §VII ¶2: release never-initialised tail blocks. O(1).
    pub fn shrink_to_watermark(&mut self) -> u32 {
        self.raw.shrink_to_watermark()
    }

    pub fn num_blocks(&self) -> u32 {
        self.raw.num_blocks()
    }

    pub fn max_blocks(&self) -> u32 {
        self.max_blocks
    }

    pub fn num_free(&self) -> u32 {
        self.raw.num_free()
    }

    pub fn block_size(&self) -> usize {
        self.raw.block_size()
    }
}

impl Drop for ResizablePool {
    fn drop(&mut self) {
        // SAFETY: the region was allocated in `new` with exactly this layout
        // and is freed only here.
        unsafe { std::alloc::dealloc(self.raw.mem_start().as_ptr(), self.layout) };
    }
}

/// Next step of the doubling schedule. `cur * 2` wraps for pools past
/// 2³¹ blocks (a plain `cur * 2` silently truncates in release builds,
/// turning "grow" into a panic inside `RawPool::grow` or worse) —
/// saturate, then cap at the reservation.
#[inline]
fn doubling_target(cur: u32, max_blocks: u32) -> u32 {
    cur.saturating_mul(2).min(max_blocks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_on_demand_up_to_max() {
        let mut p = ResizablePool::new(16, 2, 16);
        let mut held = Vec::new();
        for _ in 0..16 {
            held.push(p.allocate_or_grow().expect("within max"));
        }
        assert!(p.allocate_or_grow().is_none());
        assert_eq!(p.num_blocks(), 16);
        // All distinct addresses.
        let mut addrs: Vec<_> = held.iter().map(|q| q.as_ptr() as usize).collect();
        addrs.sort_unstable();
        addrs.dedup();
        assert_eq!(addrs.len(), 16);
        for q in held {
            // SAFETY: every pointer came from `allocate` and is freed exactly once.
            unsafe { p.deallocate(q) };
        }
        assert_eq!(p.num_free(), 16);
    }

    #[test]
    fn doubling_schedule() {
        let mut p = ResizablePool::new(8, 2, 64);
        for _ in 0..2 {
            p.allocate_or_grow().unwrap();
        }
        assert_eq!(p.num_blocks(), 2);
        p.allocate_or_grow().unwrap(); // triggers 2→4
        assert_eq!(p.num_blocks(), 4);
        for _ in 0..2 {
            p.allocate_or_grow().unwrap();
        }
        p.allocate_or_grow().unwrap(); // 4→8
        assert_eq!(p.num_blocks(), 8);
    }

    #[test]
    fn explicit_grow_is_immediate() {
        let mut p = ResizablePool::new(8, 4, 32);
        p.grow(32);
        assert_eq!(p.num_blocks(), 32);
        assert_eq!(p.num_free(), 32);
    }

    #[test]
    #[should_panic(expected = "beyond reservation")]
    fn grow_beyond_max_panics() {
        let mut p = ResizablePool::new(8, 4, 8);
        p.grow(9);
    }

    #[test]
    #[should_panic(expected = "overflows usize")]
    fn new_rejects_overflowing_reservation() {
        // Regression: `bs * max_blocks` used to be unchecked — on a
        // 64-bit target this wraps to a tiny reservation and every later
        // grow writes past it. Must fail loudly before allocating.
        let _ = ResizablePool::new(usize::MAX / 2, 1, 16);
    }

    #[test]
    #[should_panic(expected = "overflows usize")]
    fn new_rejects_align_up_wraparound() {
        // Regression: `align_up(usize::MAX, 8)` wraps to 0, which would
        // bypass the reservation check and hit `alloc` with a zero-size
        // layout. The checked align-up must panic first.
        let _ = ResizablePool::new(usize::MAX, 1, 4);
    }

    #[test]
    fn doubling_schedule_saturates_instead_of_wrapping() {
        // Regression: `cur * 2` wrapped for cur ≥ 2³¹, so a huge pool's
        // next "doubling" target became 0 (release) or panicked (debug).
        assert_eq!(doubling_target(0x8000_0000, u32::MAX), u32::MAX);
        assert_eq!(doubling_target(u32::MAX, u32::MAX), u32::MAX);
        assert_eq!(doubling_target(3, 16), 6);
        assert_eq!(doubling_target(10, 16), 16, "cap at the reservation");
        assert_eq!(doubling_target(1, 2), 2);
    }

    #[test]
    fn shrink_then_regrow() {
        let mut p = ResizablePool::new(8, 32, 32);
        let a = p.allocate().unwrap();
        // SAFETY: `a` came from `allocate` and is freed exactly once.
        unsafe { p.deallocate(a) };
        assert_eq!(p.shrink_to_watermark(), 1);
        assert_eq!(p.num_free(), 1);
        p.grow(32);
        assert_eq!(p.num_free(), 32);
        // Fully usable after shrink+regrow.
        let held: Vec<_> = (0..32).map(|_| p.allocate().unwrap()).collect();
        assert!(p.allocate().is_none());
        for q in held {
            // SAFETY: every pointer came from `allocate` and is freed exactly once.
            unsafe { p.deallocate(q) };
        }
    }
}
