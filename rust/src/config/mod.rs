//! Configuration for the serving stack: typed structs, a simple
//! `key = value` config-file format (sections via `[name]` headers), and
//! CLI overrides. (serde/toml are unavailable offline; this covers the
//! subset a launcher needs.)

use std::collections::BTreeMap;

use crate::cli::Args;

/// Raw parsed config file: `section.key → value`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RawConfig {
    pub values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parse `key = value` lines with optional `[section]` headers and
    /// `#` comments.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Self { values })
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("{key}: cannot parse `{v}`")),
        }
    }
}

/// Engine/server configuration (see DESIGN.md S21–S23).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Directory holding `*.hlo.txt` + `meta.json` artifacts.
    pub artifacts_dir: String,
    /// KV blocks available to the block manager.
    pub kv_blocks: u32,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// Max sequences scheduled per engine step.
    pub max_batch: usize,
    /// Max waiting requests before admission rejects (backpressure).
    pub queue_limit: usize,
    /// Max new tokens a request may ask for.
    pub max_tokens: u32,
    /// Scheduler policy: "fcfs" or "sjf" (shortest prompt first).
    pub policy: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            kv_blocks: 4096,
            block_tokens: 16,
            max_batch: 8,
            queue_limit: 256,
            max_tokens: 128,
            policy: "fcfs".into(),
        }
    }
}

impl ServerConfig {
    /// Layer: defaults ← config file section `[server]` ← CLI `--key`.
    pub fn from_sources(raw: Option<&RawConfig>, args: &Args) -> Result<Self, String> {
        let mut c = Self::default();
        if let Some(raw) = raw {
            c.artifacts_dir = raw
                .get("server.artifacts_dir")
                .unwrap_or(&c.artifacts_dir)
                .to_string();
            c.kv_blocks = raw.get_parse("server.kv_blocks", c.kv_blocks)?;
            c.block_tokens = raw.get_parse("server.block_tokens", c.block_tokens)?;
            c.max_batch = raw.get_parse("server.max_batch", c.max_batch)?;
            c.queue_limit = raw.get_parse("server.queue_limit", c.queue_limit)?;
            c.max_tokens = raw.get_parse("server.max_tokens", c.max_tokens)?;
            c.policy = raw.get("server.policy").unwrap_or(&c.policy).to_string();
        }
        c.artifacts_dir = args.get_or("artifacts-dir", &c.artifacts_dir).to_string();
        c.kv_blocks = args.get_u64("kv-blocks", c.kv_blocks as u64)? as u32;
        c.block_tokens = args.get_u64("block-tokens", c.block_tokens as u64)? as u32;
        c.max_batch = args.get_usize("max-batch", c.max_batch)?;
        c.queue_limit = args.get_usize("queue-limit", c.queue_limit)?;
        c.max_tokens = args.get_u64("max-tokens", c.max_tokens as u64)? as u32;
        c.policy = args.get_or("policy", &c.policy).to_string();
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.kv_blocks == 0 {
            return Err("kv_blocks must be > 0".into());
        }
        if self.block_tokens == 0 {
            return Err("block_tokens must be > 0".into());
        }
        if self.max_batch == 0 {
            return Err("max_batch must be > 0".into());
        }
        if self.policy != "fcfs" && self.policy != "sjf" {
            return Err(format!("unknown policy `{}` (fcfs|sjf)", self.policy));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_comments() {
        let raw = RawConfig::parse(
            "# top comment\n\
             global_key = 1\n\
             [server]\n\
             kv_blocks = 128  # inline comment\n\
             policy = sjf\n\
             [other]\n\
             x = y\n",
        )
        .unwrap();
        assert_eq!(raw.get("global_key"), Some("1"));
        assert_eq!(raw.get("server.kv_blocks"), Some("128"));
        assert_eq!(raw.get("server.policy"), Some("sjf"));
        assert_eq!(raw.get("other.x"), Some("y"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(RawConfig::parse("not a kv line").is_err());
    }

    #[test]
    fn layering_defaults_file_cli() {
        let raw = RawConfig::parse("[server]\nkv_blocks = 100\nmax_batch = 4\n").unwrap();
        let args =
            Args::parse(["--kv-blocks".to_string(), "200".to_string()]).unwrap();
        let c = ServerConfig::from_sources(Some(&raw), &args).unwrap();
        assert_eq!(c.kv_blocks, 200); // CLI wins
        assert_eq!(c.max_batch, 4); // file wins over default
        assert_eq!(c.block_tokens, 16); // default
    }

    #[test]
    fn validation_errors() {
        let mut c = ServerConfig::default();
        c.policy = "lifo".into();
        assert!(c.validate().is_err());
        c.policy = "fcfs".into();
        c.kv_blocks = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bad_numeric_value_reported() {
        let raw = RawConfig::parse("[server]\nkv_blocks = banana\n").unwrap();
        let args = Args::default();
        assert!(ServerConfig::from_sources(Some(&raw), &args).is_err());
    }
}
