//! `fastpool` launcher.
//!
//! Subcommands:
//!   serve      — load artifacts, run the serving engine on a generated or
//!                stdin workload, report latency/throughput.
//!   pool-demo  — quick demonstration of the paper's pool + stats.
//!   trace-gen  — emit a workload trace as CSV.
//!   info       — print artifact/runtime info.
//!
//! Benchmarks live in `benches/` (`cargo bench`); examples in `examples/`.

use fastpool::cli::Args;
use fastpool::config::{RawConfig, ServerConfig};
use fastpool::coordinator::{
    tokenizer, Admission, Engine, EngineConfig, Policy, SamplingParams, XlaBackend,
};
use fastpool::pool::{FixedPool, GuardConfig, GuardedPool};
use fastpool::runtime::Runtime;
use fastpool::util::{fmt_ns, Timer};
use fastpool::workload::{self, SizeDist};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("pool-demo") => cmd_pool_demo(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "fastpool {} — fixed-size memory pool serving framework\n\n\
         USAGE: fastpool <serve|pool-demo|trace-gen|info> [--options]\n\n\
         serve      --artifacts-dir D --requests N --max-batch B --policy fcfs|sjf\n                    --listen HOST:PORT (line-JSON server mode)\n\
                    --conservative (admission) --prompt TEXT --max-tokens N\n\
         pool-demo  --blocks N --block-size B\n\
         trace-gen  --kind game|serving|churn --out FILE\n\
         info       --artifacts-dir D",
        fastpool::VERSION
    );
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let raw = args.get("config").map(RawConfig::load).transpose()?;
    let cfg = ServerConfig::from_sources(raw.as_ref(), args)?;
    let n_requests = args.get_usize("requests", 16)?;
    let prompt_text = args.get_or("prompt", "the quick brown fox jumps over");
    let max_tokens = args.get_u64("max-tokens", 24)? as u32;

    eprintln!("loading artifacts from {} ...", cfg.artifacts_dir);
    let t = Timer::start();
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    eprintln!(
        "compiled {} executables in {:.1}s",
        rt.names().len(),
        t.elapsed_secs()
    );
    let backend = XlaBackend::new(rt)?;
    let engine_cfg = EngineConfig {
        max_batch: cfg.max_batch,
        queue_limit: cfg.queue_limit,
        admission: if args.flag("conservative") {
            Admission::Conservative
        } else {
            Admission::Optimistic
        },
        policy: if cfg.policy == "sjf" { Policy::Sjf } else { Policy::Fcfs },
        ..Default::default()
    };
    let mut engine = Engine::new(backend, engine_cfg);

    // Network mode: serve the line-JSON protocol until killed.
    if let Some(listen) = args.get("listen") {
        let listener =
            std::net::TcpListener::bind(listen).map_err(|e| format!("bind {listen}: {e}"))?;
        let server = fastpool::coordinator::Server::start(engine, listener)
            .map_err(|e| e.to_string())?;
        eprintln!(
            "listening on {} — send {{\"prompt\": \"...\", \"max_tokens\": N}} lines",
            server.addr
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // Submit the workload: the given prompt plus deterministic variations.
    let base = tokenizer::encode(prompt_text);
    let t = Timer::start();
    for i in 0..n_requests {
        let mut prompt = base.clone();
        prompt.truncate(engine.backend.runtime().meta.prefill_len - 1);
        prompt.push((i % 251) as i32); // vary the tail
        engine
            .submit(prompt, SamplingParams::greedy(max_tokens))
            .map_err(|e| format!("submit {i}: {e}"))?;
    }
    let outs = engine.run_to_completion(1_000_000)?;
    let wall = t.elapsed_secs();

    let total_tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    println!("== fastpool serve report ==");
    println!("requests:        {}", outs.len());
    println!("generated:       {total_tokens} tokens in {wall:.2}s");
    println!("throughput:      {:.1} tok/s", total_tokens as f64 / wall);
    println!("engine steps:    {}", engine.steps());
    println!(
        "model time:      {} ({} prefills, {} decodes)",
        fmt_ns(engine.backend.model_ns as f64),
        engine.backend.prefill_calls,
        engine.backend.decode_calls
    );
    println!("kv peak blocks:  {}", engine.kv.peak_used);
    println!("preemptions:     {}", engine.metrics.counter("preemptions").get());
    println!("\nmetrics:\n{}", engine.metrics.report());
    for o in outs.iter().take(3) {
        println!(
            "sample output {}: {:?} -> {:?}",
            o.id,
            tokenizer::decode(&o.prompt),
            tokenizer::decode(&o.tokens)
        );
    }
    Ok(())
}

fn cmd_pool_demo(args: &Args) -> Result<(), String> {
    let blocks = args.get_u64("blocks", 1024)? as u32;
    let block_size = args.get_usize("block-size", 64)?;
    println!("== paper pool demo: {blocks} x {block_size}B ==");

    let t = Timer::start();
    let mut pool = FixedPool::with_blocks(block_size, blocks);
    println!("create (lazy, no loops): {}", fmt_ns(t.elapsed_ns() as f64));

    let t = Timer::start();
    let ptrs: Vec<_> = (0..blocks).map(|_| pool.allocate().unwrap()).collect();
    let alloc_ns = t.elapsed_ns();
    println!(
        "allocate {blocks}: {} ({} per alloc)",
        fmt_ns(alloc_ns as f64),
        fmt_ns(alloc_ns as f64 / blocks as f64)
    );
    let t = Timer::start();
    for p in ptrs {
        // SAFETY: every pointer came from `allocate` and is freed exactly once.
        unsafe { pool.deallocate(p) };
    }
    let free_ns = t.elapsed_ns();
    println!(
        "free {blocks}:     {} ({} per free)",
        fmt_ns(free_ns as f64),
        fmt_ns(free_ns as f64 / blocks as f64)
    );
    println!("stats: {}", pool.stats().report());

    // Guarded variant demo.
    let mut g = GuardedPool::with_blocks(block_size, 8, GuardConfig::default());
    let a = g.allocate("demo:leak-me").unwrap();
    let b = g.allocate("demo:freed").unwrap();
    g.deallocate(b).map_err(|e| e.to_string())?;
    let _ = a;
    println!("guarded pool leaks: {:?}", g.leaks());
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<(), String> {
    let kind = args.get_or("kind", "churn");
    let out = args.get_or("out", "/dev/stdout");
    let seed = args.get_u64("seed", 42)?;
    let trace = match kind {
        "game" => workload::game::generate(workload::game::GameConfig::default(), seed).0,
        "serving" => {
            workload::serving::generate(workload::serving::ServingConfig::default(), seed).0
        }
        "churn" => workload::patterns::random_churn(
            args.get_u64("steps", 10_000)? as u32,
            args.get_u64("live", 256)? as u32,
            SizeDist::Fixed(args.get_u64("size", 64)? as u32),
            seed,
        ),
        k => return Err(format!("unknown kind `{k}` (game|serving|churn)")),
    };
    std::fs::write(out, trace.to_csv()).map_err(|e| e.to_string())?;
    eprintln!(
        "wrote {} ({} ops, peak live {})",
        out,
        trace.ops.len(),
        trace.peak_live
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let dir = args.get_or("artifacts-dir", "artifacts");
    let rt = Runtime::load(dir)?;
    let m = &rt.meta;
    println!("artifacts dir:  {dir}");
    println!("compile time:   {} ms", rt.compile_ms);
    println!("executables:    {:?}", rt.names());
    println!(
        "model:          d={} heads={} layers={} vocab={} params={}",
        m.d_model, m.n_heads, m.n_layers, m.vocab, m.num_params
    );
    println!(
        "kv cache:       {} blocks x {} tokens (max ctx {}, scratch {})",
        m.num_blocks, m.block_tokens, m.max_context, m.scratch_block
    );
    println!("batch variants: {:?}", m.batch_sizes);
    println!("golden tokens:  {:?}", m.golden.greedy_tokens);
    Ok(())
}
