//! Token sampling from logits: greedy argmax and top-k/temperature, all
//! deterministic given the request seed.

use crate::coordinator::request::SamplingParams;
use crate::util::Rng;

/// Sample one token from a `vocab`-sized logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, step: u64) -> i32 {
    if params.top_k == 0 {
        return argmax(logits);
    }
    // Deterministic per (seed, step) stream.
    let mut rng = Rng::new(params.seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let k = (params.top_k as usize).min(logits.len()).max(1);
    let temp = params.temperature.max(1e-3);

    // Top-k indices by logit.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let top = &idx[..k];

    // Softmax over the top-k at the given temperature.
    let max = top.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = top
        .iter()
        .map(|&i| (((logits[i] - max) / temp) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.next_f64() * total;
    for (w, &i) in weights.iter().zip(top) {
        u -= w;
        if u <= 0.0 {
            return i as i32;
        }
    }
    top[k - 1] as i32
}

/// Greedy argmax (ties → lowest index, matching numpy/jnp argmax).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max_lowest_tie() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[0.5, 0.5, 0.5]), 0);
    }

    #[test]
    fn greedy_via_sample() {
        let p = SamplingParams::greedy(1);
        assert_eq!(sample(&[1.0, 3.0, 2.0], &p, 0), 1);
    }

    #[test]
    fn topk_deterministic_per_seed_step() {
        let p = SamplingParams { top_k: 3, seed: 42, ..Default::default() };
        let logits: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = sample(&logits, &p, 7);
        let b = sample(&logits, &p, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn topk_only_picks_topk() {
        // One dominant + rest tiny: with k=2 only the top-2 can appear.
        let mut logits = vec![-100.0f32; 50];
        logits[10] = 5.0;
        logits[20] = 4.0;
        let p = SamplingParams { top_k: 2, temperature: 1.0, seed: 1, ..Default::default() };
        for step in 0..50 {
            let t = sample(&logits, &p, step);
            assert!(t == 10 || t == 20, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![0.0f32, 1.0, 0.5];
        let p = SamplingParams {
            top_k: 3,
            temperature: 0.01,
            seed: 3,
            ..Default::default()
        };
        for step in 0..20 {
            assert_eq!(sample(&logits, &p, step), 1);
        }
    }

    #[test]
    fn high_temperature_spreads() {
        let logits = vec![0.0f32, 0.2, 0.1, 0.05];
        let p = SamplingParams { top_k: 4, temperature: 50.0, seed: 9, ..Default::default() };
        let mut seen = std::collections::BTreeSet::new();
        for step in 0..200 {
            seen.insert(sample(&logits, &p, step));
        }
        assert!(seen.len() >= 3, "only saw {seen:?}");
    }
}
