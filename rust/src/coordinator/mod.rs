//! L3 coordinator: the serving framework whose allocation hot paths run on
//! the paper's pool.
//!
//! * [`request`] — request lifecycle FSM, sampling params, outputs.
//! * [`backend`] — model execution interface: [`XlaBackend`] (PJRT) and
//!   [`MockBackend`] (deterministic, for tests).
//! * [`engine`] — continuous-batching scheduler with admission control and
//!   preemption over the [`crate::kvcache`] block pool.
//! * [`admission`] — occupancy-driven admission control (hysteresis
//!   load shedding, bounded queue waits) and the typed [`SubmitError`].
//! * [`router`] — multi-engine routing (round-robin / least-loaded).
//! * [`sampler`], [`tokenizer`] — greedy/top-k sampling, byte tokenizer.

pub mod admission;
pub mod backend;
pub mod engine;
pub mod request;
pub mod router;
pub mod server;
pub mod sampler;
pub mod tokenizer;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionDecision, SubmitError};
pub use backend::{Backend, BackendGeometry, MockBackend, XlaBackend};
pub use engine::{Admission, Engine, EngineConfig, Policy};
pub use request::{FinishReason, Request, RequestOutput, RequestState, SamplingParams};
pub use router::{GlobalId, RoutePolicy, Router};
pub use server::Server;
