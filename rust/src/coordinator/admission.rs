//! Occupancy-driven admission control with hysteresis, plus the typed
//! submit-error surface.
//!
//! The engine used to degrade *reactively*: requests were admitted while
//! their prompt blocks fit and the pool preempted the youngest sequence
//! only after `CacheError::OutOfBlocks` fired mid-decode. The
//! [`AdmissionController`] turns that around: `Engine::submit` computes a
//! *committed* occupancy sample — blocks held now, plus the worst-case
//! growth of every running sequence, plus the worst case of everything
//! still queued — and the controller sheds load *before* exhaustion:
//!
//! * occupancy < low watermark → [`AdmissionDecision::Admit`]
//! * low ≤ occupancy < high   → [`AdmissionDecision::Queue`] (bounded wait)
//! * occupancy ≥ high         → [`AdmissionDecision::Reject`] and latch
//!
//! The latch is the hysteresis half: once shedding, the controller keeps
//! rejecting until occupancy falls back below the *low* watermark, so a
//! saturated server does not flap between accept and reject at the high
//! mark. A second pressure input folds in the serving pool itself
//! ([`pool_pressure`]): if any size class of the request-path
//! [`ShardedMultiPool`](crate::pool::ShardedMultiPool) runs nearly dry,
//! the controller sheds even when KV occupancy looks healthy.

use crate::pool::PoolHandle;

/// Watermark configuration for the controller. All watermarks are
/// fractions in `[0, 1]` of the KV data-block capacity (respectively the
/// per-class pool capacity for `pool_high_watermark`).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// Latch shedding at or above this committed occupancy.
    pub high_watermark: f64,
    /// Unlatch (resume admitting) strictly below this occupancy; also the
    /// boundary between `Admit` and `Queue`.
    pub low_watermark: f64,
    /// Shed when any serving-pool class's free fraction drops below
    /// `1 - pool_high_watermark` (i.e. class occupancy at or above this).
    pub pool_high_watermark: f64,
    /// Bounded wait for `Queue` decisions: a queued request that is not
    /// scheduled within this many engine steps finishes `Rejected`.
    pub max_queue_wait_steps: u64,
    /// Retry hint handed back with `Reject` decisions.
    pub retry_after_steps: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            high_watermark: 0.85,
            low_watermark: 0.70,
            pool_high_watermark: 0.95,
            max_queue_wait_steps: 512,
            retry_after_steps: 64,
        }
    }
}

/// What the controller tells `submit` to do with one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Plenty of headroom: enqueue normally.
    Admit,
    /// Pressure band: enqueue, but bound the wait — the engine stamps a
    /// queue deadline of `now + max_wait_steps`.
    Queue { max_wait_steps: u64 },
    /// Shedding: refuse the request outright with a retry hint.
    Reject { retry_after_steps: u64 },
}

/// One occupancy reading, computed by the engine at submit time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OccupancySample {
    /// Blocks held now + worst-case growth of running sequences +
    /// worst case of queued requests + the incoming request.
    pub committed_blocks: u64,
    /// KV data-block capacity (excludes the scratch block).
    pub data_blocks: u64,
    /// Highest per-class occupancy of the serving pool in `[0, 1]`
    /// (0.0 when the engine runs on the system allocator).
    pub pool_pressure: f64,
}

impl OccupancySample {
    /// Committed occupancy as a fraction of capacity. Saturates at the
    /// committed ratio even past 1.0 (over-commit is meaningful input).
    pub fn occupancy(&self) -> f64 {
        if self.data_blocks == 0 {
            1.0
        } else {
            self.committed_blocks as f64 / self.data_blocks as f64
        }
    }
}

/// Hysteresis admission controller: pure decision logic plus one bit of
/// state (the shedding latch). The engine owns one and feeds it samples.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    shedding: bool,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Self { cfg, shedding: false }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Currently latched into load shedding?
    pub fn is_shedding(&self) -> bool {
        self.shedding
    }

    /// Restore the latch (snapshot/restore path).
    pub fn set_shedding(&mut self, shedding: bool) {
        self.shedding = shedding;
    }

    /// Decide the fate of one incoming request given a fresh sample.
    pub fn decide(&mut self, sample: &OccupancySample) -> AdmissionDecision {
        let occ = sample.occupancy();
        let pool_hot = sample.pool_pressure >= self.cfg.pool_high_watermark;
        if self.shedding {
            if occ < self.cfg.low_watermark && !pool_hot {
                self.shedding = false;
            } else {
                return AdmissionDecision::Reject {
                    retry_after_steps: self.cfg.retry_after_steps,
                };
            }
        } else if occ >= self.cfg.high_watermark || pool_hot {
            self.shedding = true;
            return AdmissionDecision::Reject { retry_after_steps: self.cfg.retry_after_steps };
        }
        if occ >= self.cfg.low_watermark {
            AdmissionDecision::Queue { max_wait_steps: self.cfg.max_queue_wait_steps }
        } else {
            AdmissionDecision::Admit
        }
    }
}

/// Highest per-class occupancy of the serving pool behind `handle`, in
/// `[0, 1]`. Free counts include shard free lists, steal stashes, and
/// magazine caches (exact at quiescence — submit runs between steps), so
/// a class only reads "hot" when blocks are genuinely live. System-mode
/// handles report 0.0: malloc does not exhaust in this sense.
pub fn pool_pressure(handle: &PoolHandle) -> f64 {
    let Some(mp) = handle.multi() else { return 0.0 };
    let cap = mp.blocks_per_class();
    if cap == 0 {
        return 0.0;
    }
    let mut worst = 0.0f64;
    for ci in 0..mp.num_classes() {
        let used = cap.saturating_sub(mp.class_free(ci));
        worst = worst.max(f64::from(used) / f64::from(cap));
    }
    worst
}

// ---------------------------------------------------------------------------
// Typed submit errors
// ---------------------------------------------------------------------------

/// Why `Engine::submit` / `Router::submit` refused a request. Every
/// variant maps to a stable machine-readable wire code
/// ([`SubmitError::code`]) that `server::err_json` puts on the wire —
/// clients dispatch on the code, humans read the `Display` text.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// The prompt tokenized to nothing.
    EmptyPrompt,
    /// The prompt exceeds the model's prefill window.
    ContextOverflow { len: usize, max: usize },
    /// The waiting queue is at `queue_limit`.
    QueueFull { limit: usize },
    /// The admission controller is shedding load.
    Rejected { reason: &'static str, retry_after_steps: u64 },
    /// The tenant's committed blocks would exceed its hard quota.
    TenantQuotaExceeded { tenant: u32, committed_blocks: u64, hard_blocks: u32 },
    /// Strict tenancy is on and this tenant is not configured.
    UnknownTenant { tenant: u32 },
    /// Engine-internal failure surfaced through the submit channel.
    Internal(String),
}

impl SubmitError {
    /// Stable wire code for the `code` field of error responses. These
    /// are a compatibility surface — never rename one.
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::EmptyPrompt => "empty_prompt",
            SubmitError::ContextOverflow { .. } => "context_overflow",
            SubmitError::QueueFull { .. } => "queue_full",
            SubmitError::Rejected { .. } => "rejected",
            SubmitError::TenantQuotaExceeded { .. } => "tenant_quota",
            SubmitError::UnknownTenant { .. } => "unknown_tenant",
            SubmitError::Internal(_) => "internal",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EmptyPrompt => write!(f, "empty prompt"),
            SubmitError::ContextOverflow { len, max } => {
                write!(f, "prompt len {len} exceeds prefill window {max}")
            }
            SubmitError::QueueFull { limit } => write!(f, "queue full (limit {limit})"),
            SubmitError::Rejected { reason, retry_after_steps } => {
                write!(f, "admission rejected: {reason} (retry after ~{retry_after_steps} steps)")
            }
            SubmitError::TenantQuotaExceeded { tenant, committed_blocks, hard_blocks } => {
                write!(
                    f,
                    "tenant {tenant} over hard quota: {committed_blocks} committed blocks \
                     against a limit of {hard_blocks}"
                )
            }
            SubmitError::UnknownTenant { tenant } => write!(f, "unknown tenant {tenant}"),
            SubmitError::Internal(msg) => write!(f, "internal engine error: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

// Callers that still plumb `Result<_, String>` (the launcher, examples)
// keep working with `?` through this conversion.
impl From<SubmitError> for String {
    fn from(e: SubmitError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(committed: u64, data: u64) -> OccupancySample {
        OccupancySample { committed_blocks: committed, data_blocks: data, pool_pressure: 0.0 }
    }

    #[test]
    fn decision_bands() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(c.decide(&sample(10, 100)), AdmissionDecision::Admit);
        assert_eq!(
            c.decide(&sample(70, 100)),
            AdmissionDecision::Queue { max_wait_steps: 512 }
        );
        assert!(!c.is_shedding());
        assert_eq!(
            c.decide(&sample(85, 100)),
            AdmissionDecision::Reject { retry_after_steps: 64 }
        );
        assert!(c.is_shedding());
    }

    #[test]
    fn hysteresis_latch_holds_until_low_watermark() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        assert!(matches!(c.decide(&sample(90, 100)), AdmissionDecision::Reject { .. }));
        // Back under high but above low: still shedding (no flapping).
        assert!(matches!(c.decide(&sample(80, 100)), AdmissionDecision::Reject { .. }));
        assert!(matches!(c.decide(&sample(71, 100)), AdmissionDecision::Reject { .. }));
        // Below low: unlatch and admit in the same call.
        assert_eq!(c.decide(&sample(50, 100)), AdmissionDecision::Admit);
        assert!(!c.is_shedding());
    }

    #[test]
    fn pool_pressure_triggers_and_holds_shedding() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        let hot = OccupancySample { committed_blocks: 5, data_blocks: 100, pool_pressure: 0.97 };
        assert!(matches!(c.decide(&hot), AdmissionDecision::Reject { .. }));
        assert!(c.is_shedding());
        // KV occupancy is fine but the pool is still hot: stay latched.
        assert!(matches!(c.decide(&hot), AdmissionDecision::Reject { .. }));
        let cooled = OccupancySample { pool_pressure: 0.2, ..hot };
        assert_eq!(c.decide(&cooled), AdmissionDecision::Admit);
    }

    #[test]
    fn over_commit_and_zero_capacity_edges() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        assert!(matches!(c.decide(&sample(150, 100)), AdmissionDecision::Reject { .. }));
        let mut c = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(sample(0, 0).occupancy(), 1.0);
        assert!(matches!(c.decide(&sample(0, 0)), AdmissionDecision::Reject { .. }));
    }

    #[test]
    fn pool_pressure_reads_the_handle() {
        // System handles have no classed pool to exhaust.
        assert_eq!(pool_pressure(&PoolHandle::system()), 0.0);
        let handle = PoolHandle::builder().build();
        let idle = pool_pressure(&handle);
        assert!((0.0..=1.0).contains(&idle), "{idle}");
        // Holding live allocations must not *decrease* measured pressure.
        let held: Vec<crate::pool::PooledVec<u64>> = (0..32)
            .map(|_| {
                let mut v = crate::pool::PooledVec::with_capacity(&handle, 16);
                v.push(1u64);
                v
            })
            .collect();
        let loaded = pool_pressure(&handle);
        assert!(loaded >= idle, "{loaded} < {idle}");
        drop(held);
    }

    #[test]
    fn submit_error_codes_and_display_are_stable() {
        let cases: Vec<(SubmitError, &str)> = vec![
            (SubmitError::EmptyPrompt, "empty_prompt"),
            (SubmitError::ContextOverflow { len: 40, max: 32 }, "context_overflow"),
            (SubmitError::QueueFull { limit: 8 }, "queue_full"),
            (
                SubmitError::Rejected { reason: "occupancy", retry_after_steps: 4 },
                "rejected",
            ),
            (
                SubmitError::TenantQuotaExceeded {
                    tenant: 3,
                    committed_blocks: 9,
                    hard_blocks: 8,
                },
                "tenant_quota",
            ),
            (SubmitError::UnknownTenant { tenant: 9 }, "unknown_tenant"),
            (SubmitError::Internal("boom".into()), "internal"),
        ];
        for (err, code) in cases {
            assert_eq!(err.code(), code);
            assert!(!err.to_string().is_empty());
        }
        // The std::error::Error impl makes boxing work for callers.
        let boxed: Box<dyn std::error::Error> = Box::new(SubmitError::EmptyPrompt);
        assert_eq!(boxed.to_string(), "empty prompt");
        // And the String conversion keeps `?` working in stringly callers.
        let s: String = SubmitError::QueueFull { limit: 2 }.into();
        assert!(s.contains("queue full"), "{s}");
    }
}
