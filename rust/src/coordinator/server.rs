//! TCP front-end: newline-delimited JSON over `std::net`, one engine loop
//! thread, N connection threads. This is the deployable face of the
//! framework (the launcher's `serve --listen` mode).
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"prompt": "hello pool", "max_tokens": 16, "top_k": 0}
//! ← {"id": 3, "text": "…", "tokens": [1,2,3], "finish": "length",
//!    "queue_steps": 0, "run_steps": 17}
//! ← {"code": "queue_full", "error": "queue full (limit 256)"}
//! ```
//!
//! Every rejection carries a stable machine-readable `code` (the
//! [`SubmitError::code`] values, plus `bad_request` / `shutdown` /
//! `internal` for transport-level failures) alongside the human
//! `error` text. Clients branch on `code`; the text may change.
//!
//! The engine thread owns the `Engine` (and through it the PJRT runtime
//! and the KV block pool); connections talk to it via an mpsc channel, so
//! the model hot path stays single-threaded and allocation-free of locks.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::admission::SubmitError;
use super::backend::Backend;
use super::engine::Engine;
use super::request::{FinishReason, RequestOutput, SamplingParams};
use super::tokenizer;
use crate::util::json::{self, Json};

/// A submission handed to the engine thread.
struct Submit {
    prompt: Vec<i32>,
    params: SamplingParams,
    reply: Sender<Result<RequestOutput, SubmitError>>,
}

/// Engine steps between periodic stats dumps (pool per-class/per-shard
/// hit/steal gauges + scheduler counters, printed to stderr). The export
/// formats gauge names on every call — cheap at this cadence, but do not
/// move it into the per-step path.
const STATS_EVERY_STEPS: u64 = 512;

/// Server handle: join it to block until shutdown.
pub struct Server {
    pub addr: std::net::SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    engine_thread: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Start serving `engine` on `listener`. Returns immediately.
    pub fn start<B: Backend + Send + 'static>(
        mut engine: Engine<B>,
        listener: TcpListener,
    ) -> std::io::Result<Server> {
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx): (Sender<Submit>, Receiver<Submit>) = channel();

        // Engine loop thread.
        let shutdown_e = Arc::clone(&shutdown);
        let engine_thread = std::thread::spawn(move || {
            let mut waiters: HashMap<u64, Sender<Result<RequestOutput, SubmitError>>> =
                HashMap::new();
            let mut last_stats_step = 0u64;
            loop {
                // Drain submissions (non-blocking).
                while let Ok(sub) = rx.try_recv() {
                    match engine.submit(sub.prompt, sub.params) {
                        Ok(id) => {
                            waiters.insert(id, sub.reply);
                        }
                        Err(e) => {
                            let _ = sub.reply.send(Err(e));
                        }
                    }
                }
                if engine.has_work() {
                    if let Err(e) = engine.step() {
                        // Fatal model error: fail all waiters and stop.
                        for (_, w) in waiters.drain() {
                            let _ =
                                w.send(Err(SubmitError::Internal(format!("engine error: {e}"))));
                        }
                        return;
                    }
                    for out in engine.take_finished() {
                        if let Some(w) = waiters.remove(&out.id) {
                            let _ = w.send(Ok(out));
                        }
                    }
                    // Periodic stats dump: pool hit/steal/rehome gauges
                    // land in the registry and the whole report goes to
                    // stderr. Maintenance first, so stash blocks orphaned
                    // by exited connection threads are back on their
                    // shards before the gauges are read.
                    if engine.steps() - last_stats_step >= STATS_EVERY_STEPS {
                        last_stats_step = engine.steps();
                        engine.maintain_pool();
                        engine.export_pool_metrics();
                        eprintln!(
                            "[server stats @ step {}]\n{}",
                            engine.steps(),
                            engine.metrics.report()
                        );
                    }
                } else {
                    if shutdown_e.load(Ordering::Relaxed) {
                        // Final dump so short-lived servers still report.
                        engine.maintain_pool();
                        engine.export_pool_metrics();
                        eprintln!(
                            "[server stats @ shutdown, step {}]\n{}",
                            engine.steps(),
                            engine.metrics.report()
                        );
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            }
        });

        // Accept loop thread (connections get their own threads).
        let shutdown_a = Arc::clone(&shutdown);
        let accept_thread = std::thread::spawn(move || {
            let mut conn_threads = Vec::new();
            while !shutdown_a.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = tx.clone();
                        let shutdown_c = Arc::clone(&shutdown_a);
                        conn_threads.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, tx, shutdown_c);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for t in conn_threads {
                let _ = t.join();
            }
        });

        Ok(Server {
            addr,
            accept_thread: Some(accept_thread),
            engine_thread: Some(engine_thread),
            shutdown,
        })
    }

    /// Signal shutdown and join the threads (waits for in-flight work).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Submit>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<()> {
    // Read with a timeout so idle keep-alive connections notice shutdown
    // instead of pinning the accept thread's join forever.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(peer);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        // NOTE: `line` is cleared after successful processing, not here —
        // a read timeout can leave a partial line buffered in it.
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let response = match parse_request(&line) {
            Ok((prompt, params)) => {
                let (reply_tx, reply_rx) = channel();
                if tx.send(Submit { prompt, params, reply: reply_tx }).is_err() {
                    err_json("shutdown", "server shutting down")
                } else {
                    match reply_rx.recv() {
                        Ok(Ok(out)) => output_json(&out),
                        Ok(Err(e)) => err_json(e.code(), &e.to_string()),
                        Err(_) => err_json("internal", "engine dropped request"),
                    }
                }
            }
            Err(e) => err_json("bad_request", &e),
        };
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        line.clear();
    }
}

fn parse_request(line: &str) -> Result<(Vec<i32>, SamplingParams), String> {
    let j = json::parse(line).map_err(|e| e.to_string())?;
    let prompt_text = j.req_str("prompt").map_err(|e| e.to_string())?;
    let prompt = tokenizer::encode(prompt_text);
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let max_tokens = j
        .get("max_tokens")
        .and_then(|v| v.as_u64())
        .unwrap_or(16) as u32;
    let top_k = j.get("top_k").and_then(|v| v.as_u64()).unwrap_or(0) as u32;
    let temperature = j
        .get("temperature")
        .and_then(|v| v.as_f64())
        .unwrap_or(1.0) as f32;
    let seed = j.get("seed").and_then(|v| v.as_u64()).unwrap_or(0);
    let eos = j.get("eos").and_then(|v| v.as_u64()).map(|v| v as i32);
    let tenant = j.get("tenant").and_then(|v| v.as_u64()).unwrap_or(0) as u32;
    Ok((prompt, SamplingParams { max_tokens, eos, top_k, temperature, seed, tenant }))
}

fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::ContextOverflow => "context_overflow",
        FinishReason::Aborted => "aborted",
        FinishReason::Rejected => "rejected",
    }
}

fn output_json(out: &RequestOutput) -> String {
    json::obj(vec![
        ("id", Json::Num(out.id as f64)),
        ("text", Json::Str(tokenizer::decode(&out.tokens))),
        (
            "tokens",
            Json::Arr(out.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("finish", Json::Str(finish_str(out.finish).into())),
        ("preemptions", Json::Num(out.preemptions as f64)),
        ("queue_steps", Json::Num(out.queue_steps as f64)),
        ("run_steps", Json::Num(out.run_steps as f64)),
    ])
    .to_string()
}

/// Error line: stable machine-readable `code`, human-readable `error`.
fn err_json(code: &str, msg: &str) -> String {
    json::obj(vec![
        ("code", Json::Str(code.into())),
        ("error", Json::Str(msg.into())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_fields() {
        let (prompt, params) = parse_request(
            r#"{"prompt": "hi", "max_tokens": 5, "top_k": 3, "temperature": 0.5, "seed": 9}"#,
        )
        .unwrap();
        assert_eq!(prompt, vec![104, 105]);
        assert_eq!(params.max_tokens, 5);
        assert_eq!(params.top_k, 3);
        assert!((params.temperature - 0.5).abs() < 1e-6);
        assert_eq!(params.seed, 9);
        assert_eq!(params.eos, None);
        assert_eq!(params.tenant, 0, "tenant defaults to 0");
        let (_, params) = parse_request(r#"{"prompt": "hi", "tenant": 3}"#).unwrap();
        assert_eq!(params.tenant, 3);
    }

    #[test]
    fn wire_error_codes_are_stable() {
        // The `code` values are the wire contract — clients branch on
        // them. Renaming one is a breaking protocol change; this test is
        // the tripwire.
        let cases: Vec<(SubmitError, &str)> = vec![
            (SubmitError::EmptyPrompt, "empty_prompt"),
            (SubmitError::ContextOverflow { len: 40, max: 32 }, "context_overflow"),
            (SubmitError::QueueFull { limit: 8 }, "queue_full"),
            (SubmitError::Rejected { reason: "overloaded", retry_after_steps: 64 }, "rejected"),
            (
                SubmitError::TenantQuotaExceeded {
                    tenant: 2,
                    committed_blocks: 9,
                    hard_blocks: 8,
                },
                "tenant_quota",
            ),
            (SubmitError::UnknownTenant { tenant: 5 }, "unknown_tenant"),
            (SubmitError::Internal("boom".into()), "internal"),
        ];
        for (err, code) in cases {
            assert_eq!(err.code(), code, "{err:?}");
            let line = err_json(err.code(), &err.to_string());
            let j = json::parse(&line).unwrap();
            assert_eq!(j.req_str("code").unwrap(), code, "{line}");
            assert!(!j.req_str("error").unwrap().is_empty(), "{line}");
        }
        // Transport-level codes used by handle_conn.
        for code in ["bad_request", "shutdown", "internal"] {
            let j = json::parse(&err_json(code, "msg")).unwrap();
            assert_eq!(j.req_str("code").unwrap(), code);
        }
    }

    #[test]
    fn parse_request_fuzz_never_panics_and_rejections_are_coded() {
        // Seeded structural fuzz over the request parser: arbitrary
        // bytes, truncations, huge numerics, duplicate keys, wrong
        // types. The parser must never panic, and every rejection must
        // round-trip through err_json as a `bad_request` line that is
        // itself valid JSON.
        let mut rng = crate::util::Rng::new(0x5e1_f002);
        let corpus = [
            r#"{"prompt": "hi", "max_tokens": 5}"#,
            r#"{"prompt": "hi", "tenant": 184467440737095516159999}"#,
            r#"{"prompt": 3}"#,
            r#"{"prompt": ["x"]}"#,
            r#"{"prompt": "a", "max_tokens": -1}"#,
            r#"{"prompt": "a", "max_tokens": 1e308}"#,
            r#"{"prompt": "a", "prompt": ""}"#,
            r#"{"prompt": "a", "temperature": "hot"}"#,
            "[1,2,3]",
            "null",
            "{{{{",
            "\"prompt\"",
            "{}",
        ];
        let mut checked = 0u32;
        for case in 0..400u32 {
            let s: String = if (case as usize) < corpus.len() {
                corpus[case as usize].to_string()
            } else if rng.gen_bool(0.5) {
                // Mutate a corpus entry: truncate or splice random bytes.
                let base = corpus[rng.gen_usize(0, corpus.len())];
                let cut = rng.gen_usize(0, base.len() + 1);
                let mut m = base.as_bytes()[..cut].to_vec();
                for _ in 0..rng.gen_usize(0, 6) {
                    m.push(rng.gen_range(256) as u8);
                }
                String::from_utf8_lossy(&m).into_owned()
            } else {
                // Pure noise line.
                let n = rng.gen_usize(0, 64);
                (0..n).map(|_| (32 + rng.gen_range(95) as u8) as char).collect()
            };
            match parse_request(&s) {
                Ok((prompt, params)) => {
                    assert!(!prompt.is_empty(), "parser admitted an empty prompt: {s:?}");
                    // Values are clamped downstream; here they only must
                    // not have panicked during extraction.
                    let _ = params;
                }
                Err(e) => {
                    let line = err_json("bad_request", &e);
                    let j = json::parse(&line).unwrap_or_else(|err| {
                        panic!("err_json produced invalid JSON for {e:?}: {err}")
                    });
                    assert_eq!(j.req_str("code").unwrap(), "bad_request");
                    checked += 1;
                }
            }
        }
        assert!(checked > 100, "fuzz corpus must actually exercise rejections: {checked}");
    }

    #[test]
    fn parse_request_defaults_and_errors() {
        let (_, params) = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(params.max_tokens, 16);
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_tokens": 4}"#).is_err());
    }

    #[test]
    fn output_json_roundtrips() {
        let out = RequestOutput {
            id: 7,
            prompt: vec![104],
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            preemptions: 1,
            queue_steps: 2,
            run_steps: 3,
        };
        let s = output_json(&out);
        let j = json::parse(&s).unwrap();
        assert_eq!(j.req_usize("id").unwrap(), 7);
        assert_eq!(j.req_str("finish").unwrap(), "length");
        assert_eq!(j.req_str("text").unwrap(), "hi");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
    }
}
