//! Request model: lifecycle state machine, sampling parameters, outputs.

/// Sampling configuration for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Stop after this many generated tokens.
    pub max_tokens: u32,
    /// Optional stop token.
    pub eos: Option<i32>,
    /// 0 = greedy; k > 0 = top-k sampling.
    pub top_k: u32,
    /// Softmax temperature for top-k (ignored for greedy).
    pub temperature: f32,
    /// Per-request sampling seed (deterministic replay).
    pub seed: u64,
    /// Owning tenant for quota accounting and isolation (0 = default
    /// tenant; single-tenant callers never need to set this).
    pub tenant: u32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { max_tokens: 16, eos: None, top_k: 0, temperature: 1.0, seed: 0, tenant: 0 }
    }
}

impl SamplingParams {
    pub fn greedy(max_tokens: u32) -> Self {
        Self { max_tokens, ..Default::default() }
    }
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_tokens`.
    Length,
    /// Produced the EOS token.
    Stop,
    /// Would exceed the model's max context.
    ContextOverflow,
    /// Preempted and could not be recovered (prompt+generated exceeds the
    /// prefill window, so recompute is impossible).
    Aborted,
    /// Rejected by admission control (shed at submit, or the bounded
    /// queue wait expired before the request was ever scheduled).
    Rejected,
}

/// Lifecycle states (§DESIGN S22).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Running,
    /// Evicted under memory pressure, waiting to be re-prefilled.
    Preempted,
    Finished(FinishReason),
}

/// A generation request moving through the engine.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    pub state: RequestState,
    pub generated: Vec<i32>,
    // -- timing (engine step indices; wall times live in metrics) --
    pub arrived_step: u64,
    pub first_scheduled_step: Option<u64>,
    pub finished_step: Option<u64>,
    pub preemptions: u32,
    /// Backend failures charged to this request so far (bounded by
    /// `EngineConfig::max_retries`; exceeding the budget aborts).
    pub retries: u32,
    /// Admission `Queue` deadline: finish `Rejected` if still queued
    /// past this engine step. `None` = unbounded (plain `Admit`).
    pub queue_deadline: Option<u64>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, params: SamplingParams) -> Self {
        // Reserve the whole generation up front (bounded by max_tokens):
        // submit-time cost so the decode hot path's push never reallocates.
        let generated = Vec::with_capacity(params.max_tokens as usize);
        Self {
            id,
            prompt,
            params,
            state: RequestState::Queued,
            generated,
            arrived_step: 0,
            first_scheduled_step: None,
            finished_step: None,
            preemptions: 0,
            retries: 0,
            queue_deadline: None,
        }
    }

    /// Total tokens the sequence currently holds (prompt + generated).
    pub fn total_tokens(&self) -> u32 {
        (self.prompt.len() + self.generated.len()) as u32
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, RequestState::Finished(_))
    }

    /// Record a generated token; returns the finish reason if the request
    /// is now complete.
    pub fn push_token(&mut self, tok: i32) -> Option<FinishReason> {
        self.generated.push(tok);
        if self.params.eos == Some(tok) {
            return Some(FinishReason::Stop);
        }
        if self.generated.len() as u32 >= self.params.max_tokens {
            return Some(FinishReason::Length);
        }
        None
    }

    /// The "replay prompt" used after preemption: original prompt plus
    /// everything generated so far (recompute-based recovery).
    pub fn replay_prompt(&self) -> Vec<i32> {
        let mut p = self.prompt.clone();
        p.extend_from_slice(&self.generated);
        p
    }
}

/// Final result handed back to the caller.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    pub preemptions: u32,
    /// Engine steps spent queued before first schedule.
    pub queue_steps: u64,
    /// Engine steps from first schedule to finish.
    pub run_steps: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_token_finish_length() {
        let mut r = Request::new(1, vec![1, 2], SamplingParams::greedy(2));
        assert_eq!(r.push_token(5), None);
        assert_eq!(r.push_token(6), Some(FinishReason::Length));
        assert_eq!(r.generated, vec![5, 6]);
    }

    #[test]
    fn push_token_finish_eos() {
        let mut r = Request::new(
            1,
            vec![1],
            SamplingParams { eos: Some(0), max_tokens: 10, ..Default::default() },
        );
        assert_eq!(r.push_token(3), None);
        assert_eq!(r.push_token(0), Some(FinishReason::Stop));
    }

    #[test]
    fn replay_prompt_includes_generated() {
        let mut r = Request::new(1, vec![1, 2], SamplingParams::greedy(5));
        r.push_token(9);
        assert_eq!(r.replay_prompt(), vec![1, 2, 9]);
        assert_eq!(r.total_tokens(), 3);
    }
}
