//! Byte-level tokenizer (vocab = 256): trivial, reversible, and exactly
//! what the tiny model was trained-shaped for. A real deployment would swap
//! in BPE behind the same two functions.

/// Encode text as byte tokens.
pub fn encode(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

/// Decode byte tokens back to text (lossy on invalid UTF-8, which random
/// weights will happily produce).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = encode("hello pool");
        assert_eq!(t.len(), 10);
        assert_eq!(decode(&t), "hello pool");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo ☂";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn out_of_range_tokens_skipped() {
        assert_eq!(decode(&[104, 105, 999, -1]), "hi");
    }

    #[test]
    fn empty() {
        assert_eq!(encode(""), Vec::<i32>::new());
        assert_eq!(decode(&[]), "");
    }
}
