//! The model-execution interface the engine drives.
//!
//! Two implementations:
//! * [`XlaBackend`] — the real PJRT runtime (owns the KV literals, feeds
//!   them through every call); used by the launcher and examples.
//! * [`MockBackend`] — deterministic arithmetic "model" for coordinator
//!   unit/integration/property tests (no artifacts needed). Its next-token
//!   function depends only on (last token, sequence length), so
//!   preemption-with-recompute must reproduce identical continuations —
//!   the property the scheduler tests lean on.

use crate::runtime::Runtime;

/// Geometry the scheduler needs from a backend.
#[derive(Debug, Clone)]
pub struct BackendGeometry {
    pub vocab: usize,
    pub prefill_len: usize,
    pub block_tokens: u32,
    pub num_blocks: u32,
    pub max_blocks_per_seq: usize,
    pub scratch_block: u32,
    pub batch_sizes: Vec<usize>,
}

impl BackendGeometry {
    /// Max tokens a sequence can ever hold.
    pub fn max_context(&self) -> u32 {
        self.block_tokens * self.max_blocks_per_seq as u32
    }

    /// Smallest compiled batch variant ≥ want (fallback: largest). Runs
    /// inside the decode loop, so: one pass, no clone, no sort, no heap.
    pub fn pick_batch(&self, want: usize) -> usize {
        let mut best: Option<usize> = None;
        let mut largest = 0;
        for &b in &self.batch_sizes {
            largest = largest.max(b);
            if b >= want && best.map_or(true, |x| b < x) {
                best = Some(b);
            }
        }
        best.unwrap_or(largest)
    }
}

/// Model execution: logits are written row-major `[batch, vocab]` into a
/// caller-provided buffer, so the engine's step loop can reuse one
/// pool-backed buffer instead of receiving a fresh `Vec` per step (the
/// steady-state decode path performs zero system allocations).
pub trait Backend {
    fn geometry(&self) -> BackendGeometry;

    /// Prefill `batch` lanes. `tokens`: `[batch * prefill_len]`,
    /// `lens`: `[batch]`, `tables`: `[batch * max_blocks_per_seq]`,
    /// `logits`: out-buffer of exactly `batch * vocab`.
    fn prefill(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String>;

    /// One decode step. `tokens`/`lens`: `[batch]`, `tables`/`logits` as
    /// above.
    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String>;

    /// Whether this backend's KV addressing survives a block move: after
    /// the engine rewrites sequences' block tables (KV compaction), the
    /// next decode must still attend over the same logical content. The
    /// mock is positional (block ids are routing, not state) so moves are
    /// free; a device backend must copy the moved blocks' payloads in
    /// [`Self::apply_block_moves`] and should return `false` until it
    /// does.
    fn supports_block_moves(&self) -> bool {
        false
    }

    /// Apply a compaction's `(from, to)` block moves to device KV
    /// memory, before the next prefill/decode call. The engine invokes
    /// this with [`crate::kvcache::CompactionReport::moves`] every time
    /// it compacts; the move list is hole-free on the destination side
    /// (every `to` is dead at call time), so copies can be applied in
    /// list order without staging.
    ///
    /// The default no-op is correct only for positional backends (block
    /// ids are routing, not state — the mock). A backend that stores
    /// per-block payloads must override this with real copies or keep
    /// [`Self::supports_block_moves`] returning `false` so the engine
    /// never compacts under it.
    fn apply_block_moves(&mut self, _moves: &[(u32, u32)]) {}
}

// ---------------------------------------------------------------------------
// Real backend
// ---------------------------------------------------------------------------

/// PJRT-backed implementation; owns the KV arena literals.
pub struct XlaBackend {
    rt: Runtime,
    kv_k: xla::Literal,
    kv_v: xla::Literal,
    /// Cumulative ns inside PJRT execute (for the perf report).
    pub model_ns: u64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

// SAFETY: the xla crate's PJRT handles are raw pointers without Send
// auto-derivation, but the CPU PJRT client is thread-safe and XlaBackend
// owns its Runtime + KV literals exclusively — the engine (and hence the
// backend) is only ever driven by one thread at a time (the server moves
// the whole Engine into its single engine-loop thread).
unsafe impl Send for XlaBackend {}

impl XlaBackend {
    pub fn new(rt: Runtime) -> Result<Self, String> {
        let (kv_k, kv_v) = rt.fresh_kv()?;
        Ok(Self { rt, kv_k, kv_v, model_ns: 0, prefill_calls: 0, decode_calls: 0 })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl Backend for XlaBackend {
    fn geometry(&self) -> BackendGeometry {
        let m = &self.rt.meta;
        BackendGeometry {
            vocab: m.vocab,
            prefill_len: m.prefill_len,
            block_tokens: m.block_tokens as u32,
            num_blocks: m.num_blocks as u32,
            max_blocks_per_seq: m.max_blocks_per_seq,
            scratch_block: m.scratch_block as u32,
            batch_sizes: m.batch_sizes.clone(),
        }
    }

    fn prefill(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String> {
        let t = std::time::Instant::now();
        let (out, kk, vv) =
            self.rt.prefill(batch, tokens, lens, tables, &self.kv_k, &self.kv_v)?;
        self.kv_k = kk;
        self.kv_v = vv;
        logits.copy_from_slice(&out);
        self.model_ns += t.elapsed().as_nanos() as u64;
        self.prefill_calls += 1;
        Ok(())
    }

    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String> {
        let t = std::time::Instant::now();
        let (out, kk, vv) =
            self.rt.decode(batch, tokens, lens, tables, &self.kv_k, &self.kv_v)?;
        self.kv_k = kk;
        self.kv_v = vv;
        logits.copy_from_slice(&out);
        self.model_ns += t.elapsed().as_nanos() as u64;
        self.decode_calls += 1;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Mock backend
// ---------------------------------------------------------------------------

/// Deterministic fake model for coordinator tests.
///
/// Next-token function: `next(prev, total) = (prev*31 + total*17 + 7) % vocab`,
/// expressed as one-hot logits. Depends only on sequence *content length*
/// and last token, so recompute after preemption is bit-identical.
pub struct MockBackend {
    pub geo: BackendGeometry,
    pub prefill_calls: u64,
    pub decode_calls: u64,
    /// Fail the next N decode calls (failure-injection tests).
    pub fail_next_decodes: u32,
}

impl MockBackend {
    pub fn new() -> Self {
        Self::with_blocks(32, 16, 4)
    }

    /// `num_blocks` includes the scratch block.
    pub fn with_blocks(num_blocks: u32, block_tokens: u32, max_blocks_per_seq: usize) -> Self {
        Self {
            geo: BackendGeometry {
                vocab: 256,
                prefill_len: 32,
                block_tokens,
                num_blocks,
                max_blocks_per_seq,
                scratch_block: num_blocks - 1,
                batch_sizes: vec![1, 2, 4],
            },
            prefill_calls: 0,
            decode_calls: 0,
            fail_next_decodes: 0,
        }
    }

    pub fn next_token(prev: i32, total: u32) -> i32 {
        ((prev as i64 * 31 + total as i64 * 17 + 7) % 256) as i32
    }

    fn one_hot(&self, tok: i32, out: &mut [f32]) {
        out.fill(0.0);
        out[tok as usize % self.geo.vocab] = 1.0;
    }
}

impl Default for MockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MockBackend {
    fn geometry(&self) -> BackendGeometry {
        self.geo.clone()
    }

    fn supports_block_moves(&self) -> bool {
        true
    }

    fn prefill(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        _tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String> {
        assert_eq!(tokens.len(), batch * self.geo.prefill_len);
        let v = self.geo.vocab;
        assert_eq!(logits.len(), batch * v);
        self.prefill_calls += 1;
        for b in 0..batch {
            let len = lens[b] as usize;
            let row = &mut logits[b * v..(b + 1) * v];
            if len == 0 {
                row.fill(0.0);
                row[0] = 1.0; // pad lane: arbitrary
                continue;
            }
            let prev = tokens[b * self.geo.prefill_len + len - 1];
            let tok = Self::next_token(prev, len as u32);
            self.one_hot(tok, row);
        }
        Ok(())
    }

    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        lens: &[i32],
        _tables: &[i32],
        logits: &mut [f32],
    ) -> Result<(), String> {
        if self.fail_next_decodes > 0 {
            self.fail_next_decodes -= 1;
            return Err("injected decode failure".into());
        }
        assert_eq!(tokens.len(), batch);
        let v = self.geo.vocab;
        assert_eq!(logits.len(), batch * v);
        self.decode_calls += 1;
        for b in 0..batch {
            let row = &mut logits[b * v..(b + 1) * v];
            let tok = Self::next_token(tokens[b], lens[b] as u32 + 1);
            self.one_hot(tok, row);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefill_decode_consistency() {
        // Continuing a prompt via decode must equal prefilling the longer
        // prompt — the recompute-equivalence property.
        let mut m = MockBackend::new();
        let p = m.geo.prefill_len;
        let mut lg = vec![0.0f32; m.geo.vocab];
        let mut toks = vec![0i32; p];
        toks[0] = 10;
        toks[1] = 20;
        m.prefill(1, &toks, &[2], &[], &mut lg).unwrap();
        let t1 = crate::coordinator::sampler::argmax(&lg);

        // decode from (t1, len 2 cached) → t2.
        m.decode(1, &[t1], &[2], &[], &mut lg).unwrap();
        let t2 = crate::coordinator::sampler::argmax(&lg);

        // Replay: prefill [10, 20, t1] → must give t2.
        let mut toks2 = vec![0i32; p];
        toks2[..3].copy_from_slice(&[10, 20, t1]);
        m.prefill(1, &toks2, &[3], &[], &mut lg).unwrap();
        assert_eq!(crate::coordinator::sampler::argmax(&lg), t2);
    }

    #[test]
    fn geometry_pick_batch() {
        let g = MockBackend::new().geometry();
        assert_eq!(g.pick_batch(1), 1);
        assert_eq!(g.pick_batch(2), 2);
        assert_eq!(g.pick_batch(3), 4);
        assert_eq!(g.pick_batch(9), 4); // largest available
        assert_eq!(g.max_context(), 64);
    }

    #[test]
    fn failure_injection() {
        let mut m = MockBackend::new();
        let mut lg = vec![0.0f32; m.geo.vocab];
        m.fail_next_decodes = 1;
        assert!(m.decode(1, &[1], &[1], &[], &mut lg).is_err());
        assert!(m.decode(1, &[1], &[1], &[], &mut lg).is_ok());
    }
}
