//! Multi-engine router: spreads requests across engine replicas
//! (round-robin or least-loaded), steps them all, and merges outputs.
//! Reference shape: vllm-project/router.

use super::backend::Backend;
use super::engine::Engine;
use super::request::{RequestOutput, SamplingParams};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// A global request id: (engine index, engine-local id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalId {
    pub engine: usize,
    pub local: u64,
}

/// In-process router over engine replicas.
pub struct Router<B: Backend> {
    engines: Vec<Engine<B>>,
    policy: RoutePolicy,
    rr_next: usize,
    pub routed: Vec<u64>,
}

impl<B: Backend> Router<B> {
    pub fn new(engines: Vec<Engine<B>>, policy: RoutePolicy) -> Self {
        assert!(!engines.is_empty());
        let n = engines.len();
        Self { engines, policy, rr_next: 0, routed: vec![0; n] }
    }

    pub fn num_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engine(&self, i: usize) -> &Engine<B> {
        &self.engines[i]
    }

    pub fn engine_mut(&mut self, i: usize) -> &mut Engine<B> {
        &mut self.engines[i]
    }

    fn pick(&mut self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.engines.len();
                i
            }
            RoutePolicy::LeastLoaded => self
                .engines
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.load())
                .map(|(i, _)| i)
                .unwrap(),
        }
    }

    /// Route a request to an engine.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<GlobalId, String> {
        let engine = self.pick();
        let local = self.engines[engine].submit(prompt, params)?;
        self.routed[engine] += 1;
        Ok(GlobalId { engine, local })
    }

    /// Step every engine once; returns tokens produced.
    pub fn step_all(&mut self) -> Result<usize, String> {
        let mut produced = 0;
        for e in &mut self.engines {
            produced += e.step()?;
        }
        Ok(produced)
    }

    pub fn has_work(&self) -> bool {
        self.engines.iter().any(|e| e.has_work())
    }

    /// Drive all engines to completion; outputs tagged with engine index.
    pub fn run_to_completion(
        &mut self,
        max_steps: u64,
    ) -> Result<Vec<(usize, RequestOutput)>, String> {
        let mut steps = 0;
        while self.has_work() {
            self.step_all()?;
            steps += 1;
            if steps > max_steps {
                return Err(format!("router: no completion after {max_steps} steps"));
            }
        }
        let mut outs = Vec::new();
        for (i, e) in self.engines.iter_mut().enumerate() {
            for o in e.take_finished() {
                outs.push((i, o));
            }
        }
        Ok(outs)
    }

    pub fn total_load(&self) -> usize {
        self.engines.iter().map(|e| e.load()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use crate::coordinator::engine::EngineConfig;

    fn router(n: usize, policy: RoutePolicy) -> Router<MockBackend> {
        let engines = (0..n)
            .map(|_| Engine::new(MockBackend::new(), EngineConfig::default()))
            .collect();
        Router::new(engines, policy)
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut r = router(3, RoutePolicy::RoundRobin);
        for i in 0..9 {
            r.submit(vec![i + 1], SamplingParams::greedy(1)).unwrap();
        }
        assert_eq!(r.routed, vec![3, 3, 3]);
    }

    #[test]
    fn least_loaded_balances_uneven_queues() {
        let mut r = router(2, RoutePolicy::LeastLoaded);
        // Pre-load engine 0 directly.
        for i in 0..5 {
            r.engine_mut(0).submit(vec![i + 1], SamplingParams::greedy(4)).unwrap();
        }
        for i in 0..4 {
            let gid = r.submit(vec![i + 10], SamplingParams::greedy(4)).unwrap();
            assert_eq!(gid.engine, 1, "submission {i} should avoid loaded engine");
        }
    }

    #[test]
    fn outputs_complete_across_engines() {
        let mut r = router(2, RoutePolicy::RoundRobin);
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(r.submit(vec![i + 1, i + 2], SamplingParams::greedy(3)).unwrap());
        }
        let outs = r.run_to_completion(10_000).unwrap();
        assert_eq!(outs.len(), 6);
        for gid in ids {
            assert!(
                outs.iter().any(|(e, o)| *e == gid.engine && o.id == gid.local),
                "{gid:?} missing"
            );
        }
        assert_eq!(r.total_load(), 0);
    }
}
