//! Multi-engine router: spreads requests across engine replicas
//! (round-robin or least-loaded), steps them all, and merges outputs.
//! Reference shape: vllm-project/router.

use super::admission::SubmitError;
use super::backend::Backend;
use super::engine::Engine;
use super::request::{RequestOutput, SamplingParams};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// A global request id: (engine index, engine-local id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalId {
    pub engine: usize,
    pub local: u64,
}

/// In-process router over engine replicas.
pub struct Router<B: Backend> {
    engines: Vec<Engine<B>>,
    policy: RoutePolicy,
    rr_next: usize,
    pub routed: Vec<u64>,
}

impl<B: Backend> Router<B> {
    pub fn new(engines: Vec<Engine<B>>, policy: RoutePolicy) -> Self {
        assert!(!engines.is_empty());
        let n = engines.len();
        Self { engines, policy, rr_next: 0, routed: vec![0; n] }
    }

    pub fn num_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engine(&self, i: usize) -> &Engine<B> {
        &self.engines[i]
    }

    pub fn engine_mut(&mut self, i: usize) -> &mut Engine<B> {
        &mut self.engines[i]
    }

    /// Candidate engine for the next submission. Pure — round-robin state
    /// only advances once a submission actually lands (see `submit`).
    fn pick(&self) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => self.rr_next,
            RoutePolicy::LeastLoaded => self
                .engines
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.load())
                .map(|(i, _)| i)
                .unwrap(),
        }
    }

    /// Route a request to an engine.
    ///
    /// Fairness: the round-robin cursor advances only when a submission
    /// actually *lands*. It used to advance before the engine could
    /// reject (queue full, bad prompt), so every rejection silently
    /// skipped an engine's turn and skewed the rotation. Capacity is
    /// checked up front: a round-robin pick skips engines whose queue is
    /// full (one full engine must not block idle capacity elsewhere),
    /// with no prompt cloning or retry loop. Request-invalid submissions
    /// (empty/oversized prompt) fail identically everywhere, so they
    /// fail fast on the picked engine and leave the cursor unmoved.
    /// Least-loaded keeps its single pick — it already chose the best
    /// candidate, so a rejection there means cluster-wide pressure.
    ///
    /// An engine whose admission controller has latched into load
    /// shedding is treated like a full queue: round-robin fails over
    /// past it (`Engine::accepting`), so one saturated replica does not
    /// shed traffic the rest of the ring could serve.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<GlobalId, SubmitError> {
        let n = self.engines.len();
        let start = self.pick();
        let engine = match self.policy {
            // First engine from the cursor that is accepting; when every
            // engine rejects, let the cursor's engine surface the error.
            RoutePolicy::RoundRobin => (0..n)
                .map(|j| (start + j) % n)
                .find(|&e| self.engines[e].accepting())
                .unwrap_or(start),
            RoutePolicy::LeastLoaded => start,
        };
        let local = self.engines[engine].submit(prompt, params)?;
        if self.policy == RoutePolicy::RoundRobin {
            self.rr_next = (engine + 1) % n;
        }
        self.routed[engine] += 1;
        Ok(GlobalId { engine, local })
    }

    /// Step every engine once; returns tokens produced.
    pub fn step_all(&mut self) -> Result<usize, String> {
        let mut produced = 0;
        for e in &mut self.engines {
            produced += e.step()?;
        }
        Ok(produced)
    }

    pub fn has_work(&self) -> bool {
        self.engines.iter().any(|e| e.has_work())
    }

    /// Drive all engines to completion; outputs tagged with engine index.
    ///
    /// `max_steps` is an exact budget: at most `max_steps` calls to
    /// [`Self::step_all`] are made. (The budget check used to run *after*
    /// stepping, so a stuck router burned `max_steps + 1` steps before
    /// erroring.)
    pub fn run_to_completion(
        &mut self,
        max_steps: u64,
    ) -> Result<Vec<(usize, RequestOutput)>, String> {
        let mut steps = 0;
        while self.has_work() {
            if steps == max_steps {
                return Err(format!("router: no completion after {max_steps} steps"));
            }
            self.step_all()?;
            steps += 1;
        }
        let mut outs = Vec::new();
        for (i, e) in self.engines.iter_mut().enumerate() {
            for o in e.take_finished() {
                outs.push((i, o));
            }
        }
        Ok(outs)
    }

    pub fn total_load(&self) -> usize {
        self.engines.iter().map(|e| e.load()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;
    use crate::coordinator::engine::EngineConfig;

    fn router(n: usize, policy: RoutePolicy) -> Router<MockBackend> {
        let engines = (0..n)
            .map(|_| Engine::new(MockBackend::new(), EngineConfig::default()))
            .collect();
        Router::new(engines, policy)
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut r = router(3, RoutePolicy::RoundRobin);
        for i in 0..9 {
            r.submit(vec![i + 1], SamplingParams::greedy(1)).unwrap();
        }
        assert_eq!(r.routed, vec![3, 3, 3]);
    }

    #[test]
    fn least_loaded_balances_uneven_queues() {
        let mut r = router(2, RoutePolicy::LeastLoaded);
        // Pre-load engine 0 directly.
        for i in 0..5 {
            r.engine_mut(0).submit(vec![i + 1], SamplingParams::greedy(4)).unwrap();
        }
        for i in 0..4 {
            let gid = r.submit(vec![i + 10], SamplingParams::greedy(4)).unwrap();
            assert_eq!(gid.engine, 1, "submission {i} should avoid loaded engine");
        }
    }

    #[test]
    fn failed_submit_does_not_skew_round_robin() {
        let mut r = router(2, RoutePolicy::RoundRobin);
        let a = r.submit(vec![1], SamplingParams::greedy(1)).unwrap();
        assert_eq!(a.engine, 0);
        // A rejected submission (empty prompt) must not consume engine
        // 1's turn — the old code advanced the cursor before the engine
        // could say no, silently skipping an engine per rejection.
        assert!(r.submit(vec![], SamplingParams::greedy(1)).is_err());
        assert!(r.submit(vec![], SamplingParams::greedy(1)).is_err());
        let b = r.submit(vec![2], SamplingParams::greedy(1)).unwrap();
        assert_eq!(b.engine, 1, "rejections must not skip engine 1's turn");
        let c = r.submit(vec![3], SamplingParams::greedy(1)).unwrap();
        assert_eq!(c.engine, 0);
        assert_eq!(r.routed, vec![2, 1]);
    }

    #[test]
    fn queue_full_fails_over_instead_of_blocking_the_ring() {
        let engines = (0..2)
            .map(|_| {
                Engine::new(
                    MockBackend::new(),
                    EngineConfig { queue_limit: 1, ..Default::default() },
                )
            })
            .collect();
        let mut r = Router::new(engines, RoutePolicy::RoundRobin);
        // Fill engine 0 out-of-band: the cursor still points at it.
        r.engine_mut(0).submit(vec![1], SamplingParams::greedy(2)).unwrap();
        // A full engine must not block the ring — the submission fails
        // over to idle engine 1 and the cursor advances past it.
        let gid = r.submit(vec![2], SamplingParams::greedy(2)).unwrap();
        assert_eq!(gid.engine, 1, "failover must reach the idle engine");
        assert_eq!(r.routed, vec![0, 1]);
        // Now every queue is full: the error surfaces only after the
        // whole ring rejected, and the cursor stays put for the retry.
        let err = r.submit(vec![3], SamplingParams::greedy(2)).unwrap_err();
        assert_eq!(err, SubmitError::QueueFull { limit: 1 });
        assert!(err.to_string().contains("queue full"), "{err}");
        // Drain; the next success lands on engine 0, whose turn it still is.
        r.run_to_completion(1_000).unwrap();
        let gid = r.submit(vec![4], SamplingParams::greedy(2)).unwrap();
        assert_eq!(gid.engine, 0);
    }

    #[test]
    fn shedding_engine_fails_over_like_a_full_queue() {
        use crate::coordinator::admission::AdmissionConfig;
        // Engine 0: tiny pool + admission control → one big submission
        // latches it into load shedding. Engine 1: roomy and open.
        let small = Engine::new(
            MockBackend::with_blocks(5, 4, 4),
            EngineConfig {
                admission_ctl: Some(AdmissionConfig::default()),
                ..Default::default()
            },
        );
        let big = Engine::new(MockBackend::new(), EngineConfig::default());
        let mut r = Router::new(vec![small, big], RoutePolicy::RoundRobin);
        // 2 prompt + 14 generated = 16 tokens = 4 blocks on a 4-data-block
        // pool → occupancy 1.0 ≥ high watermark → reject + latch.
        assert!(r.engine_mut(0).submit(vec![1, 2], SamplingParams::greedy(14)).is_err());
        assert!(r.engine(0).is_shedding());
        assert!(!r.engine(0).accepting());
        // The ring's cursor points at the shedding engine; submissions
        // must fail over to engine 1 instead of being shed.
        for i in 0..3 {
            let gid = r.submit(vec![i + 1], SamplingParams::greedy(2)).unwrap();
            assert_eq!(gid.engine, 1, "submission {i} must avoid the shedding engine");
        }
        assert_eq!(r.routed, vec![0, 3]);
    }

    #[test]
    fn run_to_completion_step_budget_is_exact() {
        let mut r = router(2, RoutePolicy::RoundRobin);
        for i in 0..2 {
            r.submit(vec![i + 1], SamplingParams::greedy(50)).unwrap();
        }
        let err = r.run_to_completion(7).unwrap_err();
        assert!(err.contains("after 7 steps"), "{err}");
        for i in 0..r.num_engines() {
            // Budget is exact: each engine stepped max_steps times, not
            // max_steps + 1 as before the fix.
            assert_eq!(r.engine(i).steps(), 7, "engine {i}");
        }
        // Zero budget with work pending: error before any stepping.
        let err = r.run_to_completion(0).unwrap_err();
        assert!(err.contains("after 0 steps"), "{err}");
        assert_eq!(r.engine(0).steps(), 7);
    }

    #[test]
    fn outputs_complete_across_engines() {
        let mut r = router(2, RoutePolicy::RoundRobin);
        let mut ids = Vec::new();
        for i in 0..6 {
            ids.push(r.submit(vec![i + 1, i + 2], SamplingParams::greedy(3)).unwrap());
        }
        let outs = r.run_to_completion(10_000).unwrap();
        assert_eq!(outs.len(), 6);
        for gid in ids {
            assert!(
                outs.iter().any(|(e, o)| *e == gid.engine && o.id == gid.local),
                "{gid:?} missing"
            );
        }
        assert_eq!(r.total_load(), 0);
    }
}
