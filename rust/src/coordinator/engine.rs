//! The serving engine: continuous (iteration-level) batching over the
//! paged KV cache, with admission control, optional preemption, and
//! per-request accounting.
//!
//! One `step()` is one scheduler iteration (Orca-style):
//!
//! 1. **Admit**: pull waiting requests (FCFS or SJF) while the block pool
//!    can hold their prompts and the batch has room; run ONE batched
//!    prefill for the admitted set and sample their first tokens.
//! 2. Otherwise **decode**: one batched decode step over all running
//!    sequences (chunked to the compiled batch variants), sample, append.
//! 3. On pool exhaustion mid-decode, **preempt** the youngest running
//!    sequence: free its blocks and requeue it for recompute (its replay
//!    prompt must fit the prefill window, else it aborts).
//!
//! The KV block pool IS the paper's allocator (`kvcache::BlockAllocator`);
//! every admission/append/free on the hot path is an O(1) pool op.

use std::collections::{HashMap, VecDeque};

use super::admission::{
    self, AdmissionConfig, AdmissionController, AdmissionDecision, OccupancySample, SubmitError,
};
use super::backend::{Backend, BackendGeometry};
use super::request::{FinishReason, Request, RequestOutput, RequestState, SamplingParams};
use super::sampler;
use crate::kvcache::{CacheError, KvCacheManager, TenantQuota, TenantQuotas};
use crate::metrics::Metrics;
use crate::pool::{PoolHandle, PooledVec, SnapError, SnapReader, SnapWriter};
use crate::testkit::fault;

/// Admission policy for prompt blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admit when the prompt's blocks fit — may preempt later.
    Optimistic,
    /// Admit only when a worst-case context (max_blocks_per_seq) fits —
    /// never preempts.
    Conservative,
}

/// Scheduling order for the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    /// Shortest prompt first.
    Sjf,
}

/// Occupancy (live blocks over the touched watermark) below which
/// [`Engine::maintain_pool`] compacts the KV block grid.
const KV_COMPACT_BELOW: f64 = 0.5;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub queue_limit: usize,
    pub admission: Admission,
    pub policy: Policy,
    /// Occupancy-driven admission control (None = legacy behaviour:
    /// admit while blocks fit, preempt at exhaustion). When set, submit
    /// consults an [`AdmissionController`] over committed occupancy and
    /// the scheduler reserves each request's worst case up front, so
    /// `pool_exhaustion_events` stays 0 in steady state.
    pub admission_ctl: Option<AdmissionConfig>,
    /// Per-tenant block quotas (installed into the KV manager).
    pub quotas: TenantQuotas,
    /// Transient-failure budget per request: backend step errors charge
    /// one retry; exceeding the budget finishes the request `Aborted`.
    pub max_retries: u32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            queue_limit: 256,
            admission: Admission::Optimistic,
            policy: Policy::Fcfs,
            admission_ctl: None,
            quotas: TenantQuotas::default(),
            max_retries: 3,
        }
    }
}

/// Reusable pool-backed step buffers: sized once from the backend
/// geometry, repainted every iteration, never reallocated in steady
/// state. This is what keeps the decode loop off the system allocator —
/// the per-step `vec![…]`s the loop used to build now live on the
/// engine's [`ShardedMultiPool`](crate::pool::ShardedMultiPool).
struct StepBuffers {
    /// Decode-iteration snapshot of `running` (commit may mutate it).
    ids: PooledVec<u64>,
    tokens: PooledVec<i32>,
    lens: PooledVec<i32>,
    tables: PooledVec<i32>,
    logits: PooledVec<f32>,
}

impl StepBuffers {
    fn new(pool: &PoolHandle, geo: &BackendGeometry, max_batch: usize) -> Self {
        // Lane-indexed buffers are bounded by the largest compiled batch
        // variant (pick_batch never exceeds it); the ids snapshot by the
        // scheduler's own batch cap.
        let max_b = geo.batch_sizes.iter().copied().max().unwrap_or(1).max(max_batch);
        // The logits buffer is write-only to the engine (every Backend
        // fully overwrites `batch * vocab`): paint it once here so the
        // per-step resize is a pure length change, no memset.
        let mut logits = PooledVec::with_capacity(pool, max_b * geo.vocab);
        logits.fill_with(max_b * geo.vocab, 0.0);
        Self {
            ids: PooledVec::with_capacity(pool, max_b),
            tokens: PooledVec::with_capacity(pool, max_b * geo.prefill_len),
            lens: PooledVec::with_capacity(pool, max_b),
            tables: PooledVec::with_capacity(pool, max_b * geo.max_blocks_per_seq),
            logits,
        }
    }
}

/// The engine.
pub struct Engine<B: Backend> {
    pub backend: B,
    pub kv: KvCacheManager,
    pub cfg: EngineConfig,
    geo: BackendGeometry,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    reqs: HashMap<u64, Request>,
    finished: Vec<RequestOutput>,
    next_id: u64,
    step_count: u64,
    /// Allocation capability for the request/KV hot path; shared with the
    /// KV manager and the step buffers.
    pool: PoolHandle,
    bufs: StepBuffers,
    /// Occupancy-driven admission (None = legacy reactive behaviour).
    admission_ctl: Option<AdmissionController>,
    /// Steps before this are no-ops after a backend failure
    /// (deterministic exponential backoff; not serialized — a restored
    /// engine retries immediately).
    backoff_until: u64,
    /// Consecutive backend step failures (drives the backoff width).
    backend_error_streak: u32,
    pub metrics: Metrics,
}

impl<B: Backend> Engine<B> {
    /// Pool-backed engine (the default): per-request and per-step
    /// allocations ride a shared [`crate::pool::ShardedMultiPool`].
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        Self::with_pool(backend, cfg, PoolHandle::builder().build())
    }

    /// Engine over an explicit allocation handle. Pass
    /// [`PoolHandle::system`] for the malloc-backed ablation arm (A4) —
    /// identical engine code, no pool.
    pub fn with_pool(backend: B, cfg: EngineConfig, pool: PoolHandle) -> Self {
        let geo = backend.geometry();
        let mut kv = KvCacheManager::with_pool(
            geo.num_blocks,
            geo.block_tokens,
            geo.max_blocks_per_seq,
            pool.clone(),
        );
        kv.quotas = cfg.quotas.clone();
        let bufs = StepBuffers::new(&pool, &geo, cfg.max_batch);
        let admission_ctl = cfg.admission_ctl.clone().map(AdmissionController::new);
        Self {
            backend,
            kv,
            cfg,
            geo,
            waiting: VecDeque::new(),
            running: Vec::new(),
            reqs: HashMap::new(),
            finished: Vec::new(),
            next_id: 1,
            step_count: 0,
            pool,
            bufs,
            admission_ctl,
            backoff_until: 0,
            backend_error_streak: 0,
            metrics: Metrics::new(),
        }
    }

    /// The engine's allocation handle (shared with the KV manager).
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Publish the serving pool's per-class and per-shard hit/steal
    /// gauges into this engine's metrics registry — the payload of the
    /// server's periodic stats dump.
    pub fn export_pool_metrics(&self) {
        if let Some(mp) = self.pool.multi() {
            mp.export_metrics(&self.metrics, "pool.serving");
        }
        self.metrics.gauge("kv_peak_used").set(self.kv.peak_used as i64);
        for (tenant, held) in self.kv.tenant_usage() {
            self.metrics
                .gauge(&format!("tenant.{tenant}.kv_blocks"))
                .set(i64::from(held));
        }
    }

    /// Periodic pool maintenance (the server runs it with the stats
    /// dump): return steal-stash blocks — including chains orphaned by
    /// exited worker threads — to their owning shards' free lists, flush
    /// idle magazines (per-thread caches whose owner has exited) back to
    /// the shared tiers, and — when churn has left the KV block grid
    /// sparse and the backend is move-safe — compact it, migrating live
    /// blocks down and returning the freed tail in whole sequence-sized
    /// regions. Runs between steps only; a no-op in system mode with a
    /// dense grid.
    pub fn maintain_pool(&mut self) {
        if let Some(mp) = self.pool.multi() {
            let drained = mp.drain_stashes();
            if drained > 0 {
                self.metrics.counter("pool_stash_drained").add(drained as u64);
            }
            let flushed = mp.flush_stale_magazines();
            if flushed > 0 {
                self.metrics.counter("pool_magazines_flushed").add(flushed as u64);
            }
        }
        let pre = self.kv.occupancy();
        self.metrics.gauge("kv_occupancy_pct").set((pre * 100.0) as i64);
        if self.backend.supports_block_moves() && pre < KV_COMPACT_BELOW {
            let report = self.kv.compact(self.geo.max_blocks_per_seq as u32);
            // The block tables now address the compacted grid; the
            // backend must move the payloads before the next step reads
            // through them.
            self.backend.apply_block_moves(&report.moves);
            self.metrics.counter("kv_compactions").inc();
            self.metrics
                .counter("kv_blocks_migrated")
                .add(u64::from(report.blocks_migrated));
            self.metrics
                .counter("kv_regions_returned")
                .add(u64::from(report.regions_returned));
            self.metrics
                .gauge("kv_occupancy_post_pct")
                .set((report.post_occupancy * 100.0) as i64);
        }
    }

    /// Submit a request. Fails fast — with a typed, wire-codeable
    /// [`SubmitError`] — on overload (backpressure), quota violations,
    /// admission shedding, or an impossible prompt.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams) -> Result<u64, SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if prompt.len() > self.geo.prefill_len {
            return Err(SubmitError::ContextOverflow {
                len: prompt.len(),
                max: self.geo.prefill_len,
            });
        }
        if self.waiting.len() >= self.cfg.queue_limit {
            self.metrics.counter("rejected").inc();
            return Err(SubmitError::QueueFull { limit: self.cfg.queue_limit });
        }
        // Clamp the generation budget to the model's context window:
        // generation can never exceed it (ContextOverflow fires first), and
        // `Request::new` reserves `max_tokens` up front — an unclamped
        // client value (e.g. u32::MAX over the wire) must not turn into a
        // multi-GiB reservation.
        let mut params = params;
        params.max_tokens = params.max_tokens.min(self.geo.max_context());
        let tenant = params.tenant;
        if self.kv.quotas.strict && !self.kv.quotas.is_known(tenant) {
            self.metrics.counter("rejected").inc();
            return Err(SubmitError::UnknownTenant { tenant });
        }
        let wc = self.worst_case_blocks(prompt.len() as u32, params.max_tokens);
        if let Some(hard) = self.kv.quotas.hard_for(tenant) {
            let committed = self.tenant_committed_blocks(tenant) + wc;
            if committed > u64::from(hard) {
                self.metrics.counter("quota_rejected").inc();
                return Err(SubmitError::TenantQuotaExceeded {
                    tenant,
                    committed_blocks: committed,
                    hard_blocks: hard,
                });
            }
        }
        let mut queue_deadline = None;
        if self.admission_ctl.is_some() {
            let sample = self.occupancy_sample(wc);
            let ctl = self.admission_ctl.as_mut().expect("checked is_some above");
            let decision = ctl.decide(&sample);
            self.metrics.gauge("admission_shedding").set(i64::from(ctl.is_shedding()));
            self.metrics
                .gauge("admission_occupancy_pct")
                .set((sample.occupancy() * 100.0) as i64);
            match decision {
                AdmissionDecision::Admit => {}
                AdmissionDecision::Queue { max_wait_steps } => {
                    queue_deadline = Some(self.step_count + max_wait_steps);
                    self.metrics.counter("admission_queued").inc();
                }
                AdmissionDecision::Reject { retry_after_steps } => {
                    self.metrics.counter("admission_rejected").inc();
                    return Err(SubmitError::Rejected {
                        reason: "committed occupancy above the high watermark",
                        retry_after_steps,
                    });
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, prompt, params);
        req.arrived_step = self.step_count;
        req.queue_deadline = queue_deadline;
        self.reqs.insert(id, req);
        self.waiting.push_back(id);
        self.metrics.counter("submitted").inc();
        Ok(id)
    }

    /// Worst-case KV blocks one request can ever hold: its full context
    /// (prompt + generation budget), clamped to the per-seq table limit.
    /// Stable across preemptions — the replay prompt grows, but the total
    /// context does not — so committed-occupancy accounting never drifts.
    fn worst_case_blocks(&self, prompt_len: u32, max_tokens: u32) -> u64 {
        let blocks = self.kv.blocks_for(prompt_len + max_tokens).max(1);
        u64::from(blocks).min(self.geo.max_blocks_per_seq as u64)
    }

    fn req_worst_case_blocks(&self, req: &Request) -> u64 {
        self.worst_case_blocks(req.prompt.len() as u32, req.params.max_tokens)
    }

    /// Committed occupancy: blocks held now, plus the worst-case growth
    /// of every running sequence, plus the worst case of everything
    /// queued, plus `incoming_blocks` (the request being decided).
    fn occupancy_sample(&self, incoming_blocks: u64) -> OccupancySample {
        let mut committed = u64::from(self.kv.num_used_blocks());
        for &id in &self.running {
            if let Some(req) = self.reqs.get(&id) {
                let held = self.kv.seq(id).map_or(0, |s| s.blocks.len() as u64);
                committed += self.req_worst_case_blocks(req).saturating_sub(held);
            }
        }
        for &id in &self.waiting {
            if let Some(req) = self.reqs.get(&id) {
                committed += self.req_worst_case_blocks(req);
            }
        }
        OccupancySample {
            committed_blocks: committed + incoming_blocks,
            data_blocks: u64::from(self.kv.num_data_blocks()),
            pool_pressure: admission::pool_pressure(&self.pool),
        }
    }

    /// `tenant`'s committed blocks (held + worst-case growth of its
    /// running sequences + worst case of its queued requests) — the
    /// quantity the hard quota bounds.
    fn tenant_committed_blocks(&self, tenant: u32) -> u64 {
        let mut committed = u64::from(self.kv.tenant_held_blocks(tenant));
        for &id in &self.running {
            let Some(req) = self.reqs.get(&id) else { continue };
            if req.params.tenant == tenant {
                let held = self.kv.seq(id).map_or(0, |s| s.blocks.len() as u64);
                committed += self.req_worst_case_blocks(req).saturating_sub(held);
            }
        }
        for &id in &self.waiting {
            let Some(req) = self.reqs.get(&id) else { continue };
            if req.params.tenant == tenant {
                committed += self.req_worst_case_blocks(req);
            }
        }
        committed
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Can another request enter the waiting queue right now? (The
    /// router's capacity-aware failover checks this before routing.)
    pub fn has_queue_capacity(&self) -> bool {
        self.waiting.len() < self.cfg.queue_limit
    }

    /// Queue capacity AND the admission controller is not latched into
    /// load shedding — the router's failover signal.
    pub fn accepting(&self) -> bool {
        self.has_queue_capacity()
            && !self.admission_ctl.as_ref().is_some_and(|c| c.is_shedding())
    }

    /// Is the admission controller currently shedding load?
    pub fn is_shedding(&self) -> bool {
        self.admission_ctl.as_ref().is_some_and(|c| c.is_shedding())
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// waiting + running (router load balancing).
    pub fn load(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain finished outputs collected so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    pub fn steps(&self) -> u64 {
        self.step_count
    }

    // ------------------------------------------------------------------
    // Scheduling
    // ------------------------------------------------------------------

    /// Pick which waiting requests to admit this step.
    fn plan_admission(&mut self) -> Vec<u64> {
        if self.running.len() >= self.cfg.max_batch {
            return Vec::new();
        }
        // SJF: stable-sort waiting by prompt length.
        if self.cfg.policy == Policy::Sjf {
            let mut ids: Vec<u64> = self.waiting.iter().copied().collect();
            ids.sort_by_key(|id| self.reqs[id].replay_prompt().len());
            self.waiting = ids.into();
        }
        let mut admitted = Vec::new();
        let mut free = self.kv.num_free_blocks() as i64;
        // Budget-aware scheduling (admission control on): reserve each
        // request's exact worst case — tighter than Conservative's
        // max_blocks_per_seq, and enough to make exhaustion unreachable.
        let budget_aware = self.admission_ctl.is_some();
        if budget_aware || self.cfg.admission == Admission::Conservative {
            // Reserve worst-case growth for every running sequence so the
            // engine can never hit pool exhaustion.
            let reserved: i64 = self
                .running
                .iter()
                .map(|id| {
                    let held = self.kv.seq(*id).map_or(0, |s| s.blocks.len()) as i64;
                    let cap = if budget_aware {
                        self.reqs
                            .get(id)
                            .map_or(self.geo.max_blocks_per_seq as i64, |r| {
                                self.req_worst_case_blocks(r) as i64
                            })
                    } else {
                        self.geo.max_blocks_per_seq as i64
                    };
                    (cap - held).max(0)
                })
                .sum();
            free -= reserved;
        }
        let room = self.cfg.max_batch - self.running.len();
        while admitted.len() < room {
            let Some(&id) = self.waiting.front() else { break };
            let needed = if budget_aware {
                self.req_worst_case_blocks(&self.reqs[&id]) as i64
            } else {
                let prompt_tokens = self.reqs[&id].replay_prompt().len() as u32;
                match self.cfg.admission {
                    Admission::Optimistic => self.kv.blocks_for(prompt_tokens).max(1) as i64,
                    Admission::Conservative => self.geo.max_blocks_per_seq as i64,
                }
            };
            if needed > free {
                break; // FCFS head-of-line: wait for blocks
            }
            free -= needed;
            self.waiting.pop_front();
            admitted.push(id);
        }
        admitted
    }

    /// Finish (`Rejected`) every queued request whose bounded admission
    /// wait expired before it was ever scheduled. Preempted requests are
    /// exempt: they were admitted once and must reach a terminal state
    /// through the normal resume path.
    fn expire_queued(&mut self) {
        let expired: Vec<u64> = self
            .waiting
            .iter()
            .filter(|id| {
                self.reqs.get(id).is_some_and(|r| {
                    r.state == RequestState::Queued
                        && r.queue_deadline.is_some_and(|d| self.step_count > d)
                })
            })
            .copied()
            .collect();
        for id in expired {
            self.metrics.counter("admission_queue_timeouts").inc();
            self.finish(id, FinishReason::Rejected);
        }
    }

    /// Run one scheduler iteration. Returns the number of tokens produced.
    pub fn step(&mut self) -> Result<usize, String> {
        self.step_count += 1;
        if self.admission_ctl.is_some() {
            self.expire_queued();
        }
        if self.step_count < self.backoff_until {
            // Inside a backend-failure backoff window: burn the step
            // without touching the backend.
            self.metrics.counter("backoff_steps").inc();
            self.publish_step_gauges();
            return Ok(0);
        }
        let admitted = self.plan_admission();
        let produced = if !admitted.is_empty() {
            self.do_prefill(admitted)?
        } else if !self.running.is_empty() {
            self.do_decode()?
        } else {
            0
        };
        self.publish_step_gauges();
        Ok(produced)
    }

    fn publish_step_gauges(&self) {
        self.metrics.gauge("running").set(self.running.len() as i64);
        self.metrics.gauge("waiting").set(self.waiting.len() as i64);
        self.metrics
            .gauge("kv_free_blocks")
            .set(self.kv.num_free_blocks() as i64);
    }

    /// Record a backend step failure: bump the streak, open a
    /// deterministic exponential-backoff window (1, 2, 4, … capped at 32
    /// steps), and count it.
    fn note_backend_failure(&mut self, stage_counter: &'static str) {
        self.backend_error_streak += 1;
        let delay = (1u64 << (self.backend_error_streak.min(6) - 1)).min(32);
        self.backoff_until = self.step_count + 1 + delay;
        self.metrics.counter("backend_errors").inc();
        self.metrics.counter(stage_counter).inc();
    }

    /// Return a request to the queue head after a transient failure,
    /// charging one retry; finishes it `Aborted` once the budget is
    /// exhausted.
    fn requeue_after_failure(&mut self, id: u64) {
        let max_retries = self.cfg.max_retries;
        let Some(req) = self.reqs.get_mut(&id) else {
            debug_assert!(false, "requeue of unknown request {id}");
            return;
        };
        req.retries += 1;
        if req.retries > max_retries {
            self.finish(id, FinishReason::Aborted);
            return;
        }
        req.state = RequestState::Queued;
        if !self.waiting.contains(&id) {
            self.waiting.push_front(id);
        }
    }

    /// Drive until all work completes (or `max_steps`). Returns outputs.
    ///
    /// `max_steps` is an exact budget — at most `max_steps` calls to
    /// [`Self::step`] — matching `Router::run_to_completion` (both used
    /// to burn one extra step before erroring).
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<Vec<RequestOutput>, String> {
        let mut steps = 0;
        while self.has_work() {
            if steps == max_steps {
                return Err(format!("no completion after {max_steps} steps"));
            }
            self.step()?;
            steps += 1;
        }
        Ok(self.take_finished())
    }

    // ------------------------------------------------------------------
    // Phases
    // ------------------------------------------------------------------

    fn do_prefill(&mut self, admitted: Vec<u64>) -> Result<usize, String> {
        let p = self.geo.prefill_len;
        let mb = self.geo.max_blocks_per_seq;
        let v = self.geo.vocab;
        let batch = self.geo.pick_batch(admitted.len());
        // Register sequences + repaint the step buffers (pad lanes: len 0,
        // scratch table). The buffers are pool-backed and reused.
        self.bufs.tokens.fill_with(batch * p, 0);
        self.bufs.lens.fill_with(batch, 0);
        self.bufs.tables.fill_with(batch * mb, self.geo.scratch_block as i32);
        self.bufs.logits.set_len_initialized(batch * v);
        // Lanes that survive registration (admission can race actual
        // allocation; losers are un-admitted, not fatal).
        let mut live: Vec<(usize, u64)> = Vec::with_capacity(admitted.len());
        for (lane, &id) in admitted.iter().enumerate() {
            let Some(req) = self.reqs.get(&id) else {
                debug_assert!(false, "admitted id {id} without a request");
                continue;
            };
            let tenant = req.params.tenant;
            let replay = req.replay_prompt();
            if let Err(e) = self.kv.create_seq_for_tenant(id, replay.len() as u32, tenant) {
                // The plan's free-count check raced the real allocation
                // (or a failpoint simulated exhaustion). Un-admit: the
                // lane stays a pad lane, the request goes back to the
                // queue head with one retry charged.
                if matches!(e, CacheError::OutOfBlocks { .. }) {
                    self.metrics.counter("pool_exhaustion_events").inc();
                }
                self.metrics.counter("admission_races").inc();
                self.requeue_after_failure(id);
                continue;
            }
            self.bufs.tokens[lane * p..lane * p + replay.len()].copy_from_slice(&replay);
            self.bufs.lens[lane] = replay.len() as i32;
            // create_seq just succeeded, so the table row must exist.
            let row = &mut self.bufs.tables[lane * mb..(lane + 1) * mb];
            if self.kv.table_row_into(id, row).is_err() {
                debug_assert!(false, "freshly created seq {id} has no table row");
                continue;
            }
            let Some(req) = self.reqs.get_mut(&id) else {
                debug_assert!(false, "admitted id {id} lost its request mid-prefill");
                continue;
            };
            req.state = RequestState::Running;
            if req.first_scheduled_step.is_none() {
                req.first_scheduled_step = Some(self.step_count);
            }
            live.push((lane, id));
        }
        if live.is_empty() {
            return Ok(0);
        }
        let prefilled = self.backend.prefill(
            batch,
            &self.bufs.tokens,
            &self.bufs.lens,
            &self.bufs.tables,
            &mut self.bufs.logits,
        );
        if prefilled.is_err() {
            // Transient backend failure: nothing was sampled, so roll the
            // registered lanes back to the queue (freeing their blocks)
            // and open the backoff window. Each charged one retry.
            self.note_backend_failure("backend_prefill_errors");
            for &(_, id) in live.iter().rev() {
                let _ = self.kv.free_seq(id);
                self.requeue_after_failure(id);
            }
            return Ok(0);
        }
        self.backend_error_streak = 0;
        self.metrics.counter("prefill_batches").inc();
        // Sample first tokens (live lanes only — un-admitted lanes are
        // pads the backend ignored).
        let mut produced = 0;
        for &(lane, id) in &live {
            let tok = {
                let Some(req) = self.reqs.get(&id) else {
                    debug_assert!(false, "live lane {lane} lost its request");
                    continue;
                };
                let row = &self.bufs.logits[lane * v..(lane + 1) * v];
                sampler::sample(row, &req.params, req.total_tokens() as u64)
            };
            produced += 1;
            self.running.push(id);
            self.commit_token(id, tok)?;
        }
        Ok(produced)
    }

    fn do_decode(&mut self) -> Result<usize, String> {
        // Snapshot the running set into the reusable ids buffer — commit
        // may preempt/finish entries mid-iteration, so we must not walk
        // `self.running` directly (this replaces the per-step clone).
        let mut ids = std::mem::take(&mut self.bufs.ids);
        ids.clear();
        ids.extend_from_slice(&self.running);
        let res = self.decode_ids(&ids);
        self.bufs.ids = ids;
        res
    }

    fn decode_ids(&mut self, ids: &[u64]) -> Result<usize, String> {
        let mb = self.geo.max_blocks_per_seq;
        let v = self.geo.vocab;
        let mut produced = 0;
        // Chunk the running set into compiled batch variants.
        for chunk in ids.chunks(self.geo.pick_batch(ids.len().min(self.cfg.max_batch))) {
            let batch = self.geo.pick_batch(chunk.len());
            self.bufs.tokens.fill_with(batch, 0);
            self.bufs.lens.fill_with(batch, 0);
            self.bufs.tables.fill_with(batch * mb, self.geo.scratch_block as i32);
            self.bufs.logits.set_len_initialized(batch * v);
            for (lane, &id) in chunk.iter().enumerate() {
                // A request can vanish (aborted) or lose its cache rows
                // (preempted) through an earlier chunk's preemption
                // cascade; decoding such a lane would attend over the
                // scratch block and commit a garbage token into its
                // replay prompt. Leave it a pad lane — `lens == 0` marks
                // it, and the sampling loop below skips those (a live
                // lane always has lens ≥ 1: non-empty prompt + ≥1
                // generated token).
                let Some(req) = self.reqs.get(&id) else { continue };
                if req.state != RequestState::Running {
                    continue;
                }
                // Last token is the most recent generated one (running seqs
                // always have ≥1 generated token, from prefill sampling —
                // a violation degrades to a pad lane, never a panic).
                let Some(&last_tok) = req.generated.last() else {
                    debug_assert!(false, "running seq {id} has no generated token");
                    continue;
                };
                self.bufs.tokens[lane] = last_tok;
                // Cache currently holds total_tokens - 1 (the new token's
                // K/V is written by this decode call).
                self.bufs.lens[lane] = (req.total_tokens() - 1) as i32;
                // Running implies a cache row (create_seq at admission,
                // freed only by preempt/finish which leave Running).
                let row = &mut self.bufs.tables[lane * mb..(lane + 1) * mb];
                if self.kv.table_row_into(id, row).is_err() {
                    debug_assert!(false, "running request {id} without a cache row");
                    self.bufs.lens[lane] = 0;
                    continue;
                }
            }
            let decoded = self.backend.decode(
                batch,
                &self.bufs.tokens,
                &self.bufs.lens,
                &self.bufs.tables,
                &mut self.bufs.logits,
            );
            if decoded.is_err() {
                // Transient backend failure: no tokens were produced for
                // this chunk, the sequences keep their blocks, and the
                // next non-backoff step retries the same decode. Charge
                // each painted lane one retry; budget-exhausted requests
                // finish Aborted instead of spinning forever.
                self.note_backend_failure("backend_decode_errors");
                let max_retries = self.cfg.max_retries;
                for (lane, &id) in chunk.iter().enumerate() {
                    if self.bufs.lens[lane] == 0 {
                        continue;
                    }
                    let over_budget = {
                        let Some(req) = self.reqs.get_mut(&id) else { continue };
                        req.retries += 1;
                        req.retries > max_retries
                    };
                    if over_budget {
                        self.finish(id, FinishReason::Aborted);
                    }
                }
                return Ok(produced);
            }
            self.backend_error_streak = 0;
            self.metrics.counter("decode_batches").inc();
            for (lane, &id) in chunk.iter().enumerate() {
                // Pad lane (vanished or preempted before this chunk was
                // painted): nothing was decoded for it, nothing to commit.
                // Requests preempted mid-chunk (after painting) keep their
                // lens ≥ 1 lane and still commit, preserving the exact
                // replay prompt.
                if self.bufs.lens[lane] == 0 {
                    continue;
                }
                let tok = {
                    let Some(req) = self.reqs.get(&id) else { continue };
                    let row = &self.bufs.logits[lane * v..(lane + 1) * v];
                    sampler::sample(row, &req.params, req.total_tokens() as u64)
                };
                produced += 1;
                self.commit_token(id, tok)?;
            }
        }
        Ok(produced)
    }

    /// Append a sampled token: pool accounting, finish detection,
    /// preemption on exhaustion.
    fn commit_token(&mut self, id: u64, tok: i32) -> Result<(), String> {
        // The token's K/V slot: append_token allocates the block if this
        // token crossed a boundary. (The model already wrote K/V into the
        // slot — block ownership was guaranteed by the table row; a fresh
        // block is needed only for the NEXT step's write, so allocating
        // here keeps the table ready before the next decode.)
        // Callers resolve `id` through `reqs` before committing (prefill
        // admits it, decode paints it), so the entry must exist; degrade
        // to a dropped token rather than panicking if it does not.
        let (preempted_mid_chunk, finish) = {
            let Some(req) = self.reqs.get_mut(&id) else {
                debug_assert!(false, "commit for unknown request {id}");
                return Ok(());
            };
            (req.state == RequestState::Preempted, req.push_token(tok))
        };
        if let Some(reason) = finish {
            self.finish(id, reason);
            return Ok(());
        }
        if preempted_mid_chunk {
            // The seq lost its blocks to a preemption earlier in this same
            // chunk; the token (computed before the preemption) is kept in
            // `generated` so the replay prompt stays exact, but there is no
            // cache accounting to do.
            return Ok(());
        }
        match self.kv.append_token(id) {
            Ok(()) => Ok(()),
            Err(CacheError::ContextOverflow) => {
                self.finish(id, FinishReason::ContextOverflow);
                Ok(())
            }
            Err(CacheError::OutOfBlocks { .. }) => {
                self.metrics.counter("pool_exhaustion_events").inc();
                // Preempt an over-quota tenant's youngest sequence if one
                // exists, else the globally youngest (LIFO) — possibly
                // the one that just overflowed. `running` is non-empty
                // here (`id` itself is committing), but degrade to
                // preempting `id` rather than panicking if not.
                let Some(victim) = self.pick_preemption_victim() else {
                    debug_assert!(false, "exhaustion with nothing running");
                    self.preempt(id);
                    return Ok(());
                };
                self.preempt(victim);
                if victim != id {
                    // Retry the original append now that blocks are free.
                    if self.kv.append_token(id).is_err() {
                        // Still starved: preempt this one too.
                        self.preempt(id);
                    }
                }
                Ok(())
            }
            Err(e) => Err(e.to_string()),
        }
    }

    /// Who loses blocks under exhaustion: the youngest running sequence
    /// of a tenant over its soft quota (isolation — the noisy tenant
    /// pays first), else the globally youngest.
    fn pick_preemption_victim(&self) -> Option<u64> {
        for &id in self.running.iter().rev() {
            let Some(req) = self.reqs.get(&id) else { continue };
            let t = req.params.tenant;
            if let Some(soft) = self.kv.quotas.soft_for(t) {
                if self.kv.tenant_held_blocks(t) > soft {
                    return Some(id);
                }
            }
        }
        self.running.last().copied()
    }

    fn preempt(&mut self, id: u64) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        // Victims come from `running`, whose ids stay in `reqs` until
        // `finish` removes them — a miss is an engine bug, not a state
        // a release build should die on.
        let Some(req) = self.reqs.get_mut(&id) else {
            debug_assert!(false, "preempt of unknown request {id}");
            return;
        };
        req.preemptions += 1;
        self.metrics.counter("preemptions").inc();
        if req.replay_prompt().len() <= self.geo.prefill_len {
            req.state = RequestState::Preempted;
            self.waiting.push_front(id);
        } else {
            // Cannot recompute through the prefill window.
            self.finish(id, FinishReason::Aborted);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        self.waiting.retain(|&r| r != id); // may finish while preempted
        // Every finish call site resolved `id` through `reqs` first, so
        // the entry must exist; a miss means the output is already gone.
        let Some(mut req) = self.reqs.remove(&id) else {
            debug_assert!(false, "finish of unknown request {id}");
            return;
        };
        req.state = RequestState::Finished(reason);
        req.finished_step = Some(self.step_count);
        let first = req.first_scheduled_step.unwrap_or(self.step_count);
        self.metrics.counter("finished").inc();
        self.metrics
            .histogram("queue_steps")
            .record(first.saturating_sub(req.arrived_step));
        // The request is dead: move its buffers into the output instead of
        // cloning them.
        self.finished.push(RequestOutput {
            id,
            prompt: req.prompt,
            tokens: req.generated,
            finish: reason,
            preemptions: req.preemptions,
            queue_steps: first.saturating_sub(req.arrived_step),
            run_steps: self.step_count.saturating_sub(first),
        });
    }

    // ------------------------------------------------------------------
    // Snapshot / restore
    // ------------------------------------------------------------------

    /// Serialise the engine's complete logical state — scheduler config,
    /// queue order, every in-flight request, pending outputs, and the KV
    /// manager (allocator + block tables) — to a byte buffer. Pool-backed
    /// storage (step buffers, per-sequence tables) is rebuilt from the
    /// restoring process's pool, so the snapshot is process-portable.
    ///
    /// Call between steps, never mid-step. Metrics are observability, not
    /// replay state: a restored engine starts fresh counters. Backend
    /// device state is out of scope — [`Self::restore`] pairs the bytes
    /// with a backend whose geometry matches; for the deterministic mock
    /// that is enough for the restored engine to resume decoding
    /// bit-identically.
    pub fn snapshot(&self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        w.put_u32(ENGINE_SNAP_MAGIC);
        w.put_u32(ENGINE_SNAP_VERSION);
        w.put_u64(self.cfg.max_batch as u64);
        w.put_u64(self.cfg.queue_limit as u64);
        w.put_u8(match self.cfg.admission {
            Admission::Optimistic => 0,
            Admission::Conservative => 1,
        });
        w.put_u8(match self.cfg.policy {
            Policy::Fcfs => 0,
            Policy::Sjf => 1,
        });
        w.put_u32(self.cfg.max_retries);
        // Admission controller: config plus the latched shedding bit
        // (hysteresis state must survive a restore, or a saturated
        // engine would resume admitting straight into exhaustion).
        match &self.admission_ctl {
            None => w.put_u8(0),
            Some(ctl) => {
                w.put_u8(1);
                let c = ctl.config();
                w.put_u64(c.high_watermark.to_bits());
                w.put_u64(c.low_watermark.to_bits());
                w.put_u64(c.pool_high_watermark.to_bits());
                w.put_u64(c.max_queue_wait_steps);
                w.put_u64(c.retry_after_steps);
                w.put_u8(u8::from(ctl.is_shedding()));
            }
        }
        // Tenant quota policy (the KV snapshot carries only usage).
        let q = &self.cfg.quotas;
        w.put_u8(u8::from(q.strict));
        put_opt_u32(&mut w, q.default_soft);
        put_opt_u32(&mut w, q.default_hard);
        w.put_u32(q.per_tenant.len() as u32);
        for &(tenant, tq) in &q.per_tenant {
            w.put_u32(tenant);
            put_opt_u32(&mut w, tq.soft);
            put_opt_u32(&mut w, tq.hard);
        }
        w.put_u64(self.step_count);
        w.put_u64(self.next_id);
        w.put_u32(self.waiting.len() as u32);
        for &id in &self.waiting {
            w.put_u64(id);
        }
        w.put_u32(self.running.len() as u32);
        for &id in &self.running {
            w.put_u64(id);
        }
        let mut ids: Vec<u64> = self.reqs.keys().copied().collect();
        ids.sort_unstable();
        w.put_u32(ids.len() as u32);
        for id in ids {
            put_request(&mut w, &self.reqs[&id]);
        }
        w.put_u32(self.finished.len() as u32);
        for o in &self.finished {
            put_output(&mut w, o);
        }
        self.kv.snapshot_into(&mut w);
        w.into_bytes()
    }

    /// Rebuild an engine from [`Self::snapshot`] bytes over `backend`
    /// and `pool`. The backend's geometry must match the snapshot's KV
    /// shape ([`SnapError::ConfigMismatch`] otherwise); the stream is
    /// structurally validated, never trusted.
    pub fn restore(backend: B, pool: PoolHandle, bytes: &[u8]) -> Result<Self, SnapError> {
        if fault::should_fail("snapshot.decode") {
            return Err(SnapError::Corrupt("failpoint snapshot.decode"));
        }
        let mut r = SnapReader::new(bytes);
        if r.u32()? != ENGINE_SNAP_MAGIC {
            return Err(SnapError::BadMagic);
        }
        let ver = r.u32()?;
        if ver != ENGINE_SNAP_VERSION {
            return Err(SnapError::BadVersion(ver));
        }
        let max_batch = r.u64()? as usize;
        let queue_limit = r.u64()? as usize;
        let admission = match r.u8()? {
            0 => Admission::Optimistic,
            1 => Admission::Conservative,
            _ => return Err(SnapError::Corrupt("admission policy")),
        };
        let policy = match r.u8()? {
            0 => Policy::Fcfs,
            1 => Policy::Sjf,
            _ => return Err(SnapError::Corrupt("queue policy")),
        };
        let max_retries = r.u32()?;
        let (admission_cfg, shedding) = match r.u8()? {
            0 => (None, false),
            1 => {
                let high_watermark = f64::from_bits(r.u64()?);
                let low_watermark = f64::from_bits(r.u64()?);
                let pool_high_watermark = f64::from_bits(r.u64()?);
                for w in [high_watermark, low_watermark, pool_high_watermark] {
                    if !w.is_finite() || !(0.0..=1.0).contains(&w) {
                        return Err(SnapError::Corrupt("admission watermark out of [0, 1]"));
                    }
                }
                let max_queue_wait_steps = r.u64()?;
                let retry_after_steps = r.u64()?;
                let shedding = match r.u8()? {
                    0 => false,
                    1 => true,
                    _ => return Err(SnapError::Corrupt("shedding flag")),
                };
                let c = AdmissionConfig {
                    high_watermark,
                    low_watermark,
                    pool_high_watermark,
                    max_queue_wait_steps,
                    retry_after_steps,
                };
                (Some(c), shedding)
            }
            _ => return Err(SnapError::Corrupt("admission controller tag")),
        };
        let strict = match r.u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapError::Corrupt("quota strict flag")),
        };
        let default_soft = get_opt_u32(&mut r)?;
        let default_hard = get_opt_u32(&mut r)?;
        let n_quota = r.u32()?;
        let mut per_tenant = Vec::new();
        for _ in 0..n_quota {
            let tenant = r.u32()?;
            let soft = get_opt_u32(&mut r)?;
            let hard = get_opt_u32(&mut r)?;
            if per_tenant.iter().any(|&(t, _)| t == tenant) {
                return Err(SnapError::Corrupt("duplicate tenant quota entry"));
            }
            per_tenant.push((tenant, TenantQuota { soft, hard }));
        }
        let quotas = TenantQuotas { default_soft, default_hard, per_tenant, strict };
        let cfg = EngineConfig {
            max_batch,
            queue_limit,
            admission,
            policy,
            admission_ctl: admission_cfg,
            quotas,
            max_retries,
        };
        let step_count = r.u64()?;
        let next_id = r.u64()?;
        let n_waiting = r.u32()?;
        let mut waiting = VecDeque::new();
        for _ in 0..n_waiting {
            waiting.push_back(r.u64()?);
        }
        let n_running = r.u32()?;
        let mut running = Vec::new();
        for _ in 0..n_running {
            running.push(r.u64()?);
        }
        let n_reqs = r.u32()?;
        let mut reqs = HashMap::new();
        for _ in 0..n_reqs {
            let req = get_request(&mut r)?;
            if req.id >= next_id {
                return Err(SnapError::Corrupt("request id at or above next_id"));
            }
            if reqs.insert(req.id, req).is_some() {
                return Err(SnapError::Corrupt("duplicate request id"));
            }
        }
        for id in waiting.iter().chain(running.iter()) {
            if !reqs.contains_key(id) {
                return Err(SnapError::Corrupt("queued id without a request"));
            }
        }
        let n_fin = r.u32()?;
        let mut finished = Vec::new();
        for _ in 0..n_fin {
            finished.push(get_output(&mut r)?);
        }
        let mut kv = KvCacheManager::restore_from(&mut r, pool.clone())?;
        // Quotas are policy, not cache state: the engine stream carries
        // them (validated above), the KV restore only rebuilds usage.
        kv.quotas = cfg.quotas.clone();
        r.expect_end()?;
        for id in &running {
            if kv.seq(*id).is_none() {
                return Err(SnapError::Corrupt("running id without a cache row"));
            }
        }
        let geo = backend.geometry();
        if kv.block_tokens != geo.block_tokens
            || kv.max_blocks_per_seq != geo.max_blocks_per_seq
            || kv.scratch_block != geo.scratch_block
        {
            return Err(SnapError::ConfigMismatch("backend geometry does not match snapshot"));
        }
        let bufs = StepBuffers::new(&pool, &geo, cfg.max_batch);
        let admission_ctl = cfg.admission_ctl.clone().map(|c| {
            let mut ctl = AdmissionController::new(c);
            ctl.set_shedding(shedding);
            ctl
        });
        Ok(Self {
            backend,
            kv,
            cfg,
            geo,
            waiting,
            running,
            reqs,
            finished,
            next_id,
            step_count,
            pool,
            bufs,
            admission_ctl,
            backoff_until: 0,
            backend_error_streak: 0,
            metrics: Metrics::new(),
        })
    }
}

const ENGINE_SNAP_MAGIC: u32 = u32::from_le_bytes(*b"FPEN");
// v2: + max_retries, admission-controller state, tenant quota policy,
// and per-request tenant / retries / queue_deadline.
const ENGINE_SNAP_VERSION: u32 = 2;

fn put_tokens(w: &mut SnapWriter, toks: &[i32]) {
    w.put_u32(toks.len() as u32);
    for &t in toks {
        w.put_u32(t as u32);
    }
}

fn get_tokens(r: &mut SnapReader<'_>) -> Result<Vec<i32>, SnapError> {
    let n = r.u32()?;
    let mut v = Vec::new();
    for _ in 0..n {
        v.push(r.u32()? as i32);
    }
    Ok(v)
}

fn put_finish(w: &mut SnapWriter, f: FinishReason) {
    w.put_u8(match f {
        FinishReason::Length => 0,
        FinishReason::Stop => 1,
        FinishReason::ContextOverflow => 2,
        FinishReason::Aborted => 3,
        FinishReason::Rejected => 4,
    });
}

fn get_finish(r: &mut SnapReader<'_>) -> Result<FinishReason, SnapError> {
    Ok(match r.u8()? {
        0 => FinishReason::Length,
        1 => FinishReason::Stop,
        2 => FinishReason::ContextOverflow,
        3 => FinishReason::Aborted,
        4 => FinishReason::Rejected,
        _ => return Err(SnapError::Corrupt("finish reason")),
    })
}

fn put_opt_u64(w: &mut SnapWriter, v: Option<u64>) {
    match v {
        None => w.put_u8(0),
        Some(x) => {
            w.put_u8(1);
            w.put_u64(x);
        }
    }
}

fn get_opt_u64(r: &mut SnapReader<'_>) -> Result<Option<u64>, SnapError> {
    Ok(match r.u8()? {
        0 => None,
        1 => Some(r.u64()?),
        _ => return Err(SnapError::Corrupt("option tag")),
    })
}

fn put_opt_u32(w: &mut SnapWriter, v: Option<u32>) {
    match v {
        None => w.put_u8(0),
        Some(x) => {
            w.put_u8(1);
            w.put_u32(x);
        }
    }
}

fn get_opt_u32(r: &mut SnapReader<'_>) -> Result<Option<u32>, SnapError> {
    Ok(match r.u8()? {
        0 => None,
        1 => Some(r.u32()?),
        _ => return Err(SnapError::Corrupt("option tag")),
    })
}

fn put_request(w: &mut SnapWriter, req: &Request) {
    w.put_u64(req.id);
    put_tokens(w, &req.prompt);
    put_tokens(w, &req.generated);
    w.put_u32(req.params.max_tokens);
    match req.params.eos {
        None => w.put_u8(0),
        Some(e) => {
            w.put_u8(1);
            w.put_u32(e as u32);
        }
    }
    w.put_u32(req.params.top_k);
    w.put_u32(req.params.temperature.to_bits());
    w.put_u64(req.params.seed);
    w.put_u32(req.params.tenant);
    match req.state {
        RequestState::Queued => w.put_u8(0),
        RequestState::Running => w.put_u8(1),
        RequestState::Preempted => w.put_u8(2),
        RequestState::Finished(f) => {
            w.put_u8(3);
            put_finish(w, f);
        }
    }
    w.put_u64(req.arrived_step);
    put_opt_u64(w, req.first_scheduled_step);
    put_opt_u64(w, req.finished_step);
    w.put_u32(req.preemptions);
    w.put_u32(req.retries);
    put_opt_u64(w, req.queue_deadline);
}

fn get_request(r: &mut SnapReader<'_>) -> Result<Request, SnapError> {
    let id = r.u64()?;
    let prompt = get_tokens(r)?;
    if prompt.is_empty() {
        return Err(SnapError::Corrupt("empty request prompt"));
    }
    let generated_vals = get_tokens(r)?;
    let max_tokens = r.u32()?;
    // `Request::new` reserves `max_tokens` up front; bound it so a
    // corrupt stream cannot turn into a multi-GiB reservation (submit
    // clamps to the model context, far below this).
    if max_tokens > 1 << 22 {
        return Err(SnapError::Corrupt("implausible max_tokens"));
    }
    if generated_vals.len() as u32 > max_tokens {
        return Err(SnapError::Corrupt("generated exceeds max_tokens"));
    }
    let eos = match r.u8()? {
        0 => None,
        1 => Some(r.u32()? as i32),
        _ => return Err(SnapError::Corrupt("eos tag")),
    };
    let top_k = r.u32()?;
    let temperature = f32::from_bits(r.u32()?);
    let seed = r.u64()?;
    let tenant = r.u32()?;
    let params = SamplingParams { max_tokens, eos, top_k, temperature, seed, tenant };
    let state = match r.u8()? {
        0 => RequestState::Queued,
        1 => RequestState::Running,
        2 => RequestState::Preempted,
        3 => RequestState::Finished(get_finish(r)?),
        _ => return Err(SnapError::Corrupt("request state")),
    };
    let arrived_step = r.u64()?;
    let first_scheduled_step = get_opt_u64(r)?;
    let finished_step = get_opt_u64(r)?;
    let preemptions = r.u32()?;
    let retries = r.u32()?;
    let queue_deadline = get_opt_u64(r)?;
    // Rebuild through `Request::new` so the generated buffer keeps its
    // submit-time reservation (push never reallocates on the hot path).
    let mut req = Request::new(id, prompt, params);
    req.generated.extend_from_slice(&generated_vals);
    req.state = state;
    req.arrived_step = arrived_step;
    req.first_scheduled_step = first_scheduled_step;
    req.finished_step = finished_step;
    req.preemptions = preemptions;
    req.retries = retries;
    req.queue_deadline = queue_deadline;
    Ok(req)
}

fn put_output(w: &mut SnapWriter, o: &RequestOutput) {
    w.put_u64(o.id);
    put_tokens(w, &o.prompt);
    put_tokens(w, &o.tokens);
    put_finish(w, o.finish);
    w.put_u32(o.preemptions);
    w.put_u64(o.queue_steps);
    w.put_u64(o.run_steps);
}

fn get_output(r: &mut SnapReader<'_>) -> Result<RequestOutput, SnapError> {
    Ok(RequestOutput {
        id: r.u64()?,
        prompt: get_tokens(r)?,
        tokens: get_tokens(r)?,
        finish: get_finish(r)?,
        preemptions: r.u32()?,
        queue_steps: r.u64()?,
        run_steps: r.u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;

    fn engine(cfg: EngineConfig) -> Engine<MockBackend> {
        Engine::new(MockBackend::new(), cfg)
    }

    /// Expected mock continuation for a prompt.
    fn mock_expect(prompt: &[i32], n: usize) -> Vec<i32> {
        let mut out = Vec::new();
        let mut prev = *prompt.last().unwrap();
        let mut total = prompt.len() as u32;
        for _ in 0..n {
            let t = MockBackend::next_token(prev, total);
            out.push(t);
            prev = t;
            total += 1;
        }
        out
    }

    #[test]
    fn single_request_end_to_end() {
        let mut e = engine(EngineConfig::default());
        let id = e.submit(vec![10, 20, 30], SamplingParams::greedy(6)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, id);
        assert_eq!(outs[0].finish, FinishReason::Length);
        assert_eq!(outs[0].tokens, mock_expect(&[10, 20, 30], 6));
    }

    #[test]
    fn batch_of_requests_all_correct() {
        let mut e = engine(EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> =
            (0..6).map(|i| vec![i + 1, (i + 2) * 3, (i * 7) % 250]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(8)).unwrap();
        }
        let mut outs = e.run_to_completion(10_000).unwrap();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 6);
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.tokens, mock_expect(p, 8), "req {}", o.id);
            assert_eq!(o.finish, FinishReason::Length);
        }
        // All KV blocks returned to the pool.
        assert_eq!(e.kv.num_seqs(), 0);
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn queue_limit_backpressure() {
        let mut e = engine(EngineConfig { queue_limit: 2, ..Default::default() });
        e.submit(vec![1], SamplingParams::greedy(1)).unwrap();
        e.submit(vec![2], SamplingParams::greedy(1)).unwrap();
        assert!(e.submit(vec![3], SamplingParams::greedy(1)).is_err());
        assert_eq!(e.metrics.counter("rejected").get(), 1);
    }

    #[test]
    fn prompt_too_long_rejected() {
        let mut e = engine(EngineConfig::default());
        let long = vec![1i32; 33]; // prefill window is 32
        assert!(e.submit(long, SamplingParams::greedy(1)).is_err());
        assert!(e.submit(vec![1i32; 32], SamplingParams::greedy(1)).is_ok());
    }

    #[test]
    fn eos_stops_early() {
        // Find the first mock token for this prompt and set it as EOS.
        let prompt = vec![5, 6];
        let first = mock_expect(&prompt, 1)[0];
        let mut e = engine(EngineConfig::default());
        e.submit(
            prompt,
            SamplingParams { eos: Some(first), max_tokens: 50, ..Default::default() },
        )
        .unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert_eq!(outs[0].tokens.len(), 1);
    }

    #[test]
    fn context_overflow_finishes_cleanly() {
        // max context = 4 blocks × 16 tokens = 64; prompt 30 + max_tokens
        // 100 would exceed → ContextOverflow.
        let mut e = engine(EngineConfig::default());
        e.submit(vec![9; 30], SamplingParams::greedy(100)).unwrap();
        let outs = e.run_to_completion(10_000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::ContextOverflow);
        // 30 prompt + 34 cached + 1 final uncached token = 35 max.
        assert!(outs[0].tokens.len() <= 35, "{}", outs[0].tokens.len());
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn preemption_recovers_identical_output() {
        // Tiny pool (9 = 8 data + scratch blocks) with long generations
        // forces preemption; the mock's determinism means outputs must be
        // IDENTICAL to an uncontended run.
        let be = MockBackend::with_blocks(9, 4, 4); // blocks of 4 tokens
        let mut e = Engine::new(be, EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![i * 3 + 1, i + 2]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(10)).unwrap();
        }
        let mut outs = e.run_to_completion(100_000).unwrap();
        outs.sort_by_key(|o| o.id);
        let preempted: u32 = outs.iter().map(|o| o.preemptions).sum();
        assert!(preempted > 0, "test should exercise preemption");
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
            assert_eq!(o.tokens, mock_expect(p, 10), "req {} after preemption", o.id);
        }
        assert_eq!(e.metrics.counter("preemptions").get() as u32, preempted);
    }

    #[test]
    fn conservative_admission_never_preempts() {
        let be = MockBackend::with_blocks(9, 4, 4);
        let mut e = Engine::new(
            be,
            EngineConfig {
                max_batch: 4,
                admission: Admission::Conservative,
                ..Default::default()
            },
        );
        for i in 0..4 {
            e.submit(vec![i + 1, i + 5], SamplingParams::greedy(10)).unwrap();
        }
        let outs = e.run_to_completion(100_000).unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(e.metrics.counter("preemptions").get(), 0);
        assert!(outs.iter().all(|o| o.finish == FinishReason::Length));
    }

    #[test]
    fn sjf_schedules_short_prompts_first() {
        let mut e = engine(EngineConfig {
            max_batch: 1,
            policy: Policy::Sjf,
            ..Default::default()
        });
        let long = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let short = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(short) < pos(long), "short prompt should finish first");
    }

    #[test]
    fn fcfs_preserves_order_single_lane() {
        let mut e = engine(EngineConfig { max_batch: 1, ..Default::default() });
        let a = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let b = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(a) < pos(b));
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1, 2], SamplingParams::greedy(3)).unwrap();
        e.run_to_completion(1000).unwrap();
        assert_eq!(e.metrics.counter("submitted").get(), 1);
        assert_eq!(e.metrics.counter("finished").get(), 1);
        assert!(e.metrics.counter("decode_batches").get() >= 1);
        assert!(e.metrics.counter("prefill_batches").get() >= 1);
    }

    #[test]
    fn run_to_completion_budget_is_exact() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1], SamplingParams::greedy(50)).unwrap();
        let err = e.run_to_completion(3).unwrap_err();
        assert!(err.contains("after 3 steps"), "{err}");
        assert_eq!(e.steps(), 3, "budget is exact, not max_steps + 1");
    }

    #[test]
    fn idle_step_is_noop() {
        let mut e = engine(EngineConfig::default());
        assert_eq!(e.step().unwrap(), 0);
        assert!(!e.has_work());
    }

    #[test]
    fn pool_backed_and_malloc_backed_agree() {
        // A4's correctness leg: the two ablation arms run identical
        // engine code and must produce identical outputs.
        let run = |pool: crate::pool::PoolHandle| {
            let mut e = Engine::with_pool(
                MockBackend::new(),
                EngineConfig { max_batch: 4, ..Default::default() },
                pool,
            );
            for i in 0..6 {
                e.submit(vec![i + 1, 2 * i + 3], SamplingParams::greedy(12)).unwrap();
            }
            let mut outs = e.run_to_completion(100_000).unwrap();
            outs.sort_by_key(|o| o.id);
            outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
        };
        let pooled = run(crate::pool::PoolHandle::builder().build());
        let malloc = run(crate::pool::PoolHandle::system());
        assert_eq!(pooled, malloc);
    }

    #[test]
    fn pool_serves_the_steady_state_hot_path() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1, 2, 3], SamplingParams::greedy(20)).unwrap();
        e.run_to_completion(10_000).unwrap();
        let mp = e.pool().multi().expect("default engine is pool-backed");
        let hits: u64 = (0..mp.num_classes()).map(|c| mp.class_hits(c)).sum();
        assert!(hits > 0, "step buffers and KV tables must be pool-served");
        assert!(mp.pool_hit_rate() > 0.9, "{}", mp.pool_hit_rate());
        // The serving arm runs in cached mode: the same workload must
        // have ridden the per-thread magazines.
        assert!(mp.magazines_enabled(), "serving pool defaults to cached mode");
        let ms = mp.magazine_stats();
        assert!(
            ms.hits + ms.refills > 0,
            "request/KV allocations must ride the magazine layer: {ms:?}"
        );
    }

    #[test]
    fn export_pool_metrics_publishes_gauges() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![4, 5], SamplingParams::greedy(4)).unwrap();
        e.run_to_completion(1000).unwrap();
        e.export_pool_metrics();
        let r = e.metrics.report();
        assert!(r.contains("pool.serving.hit_rate_pct"), "{r}");
        assert!(r.contains("pool.serving.c16.shards"), "{r}");
        assert!(r.contains("pool.serving.rehomes_total"), "{r}");
        assert!(r.contains("pool.serving.c16.local_hit_pct"), "{r}");
        assert!(r.contains("pool.serving.magazine_hits_total"), "{r}");
        assert!(r.contains("pool.serving.magazine_refills_total"), "{r}");
        assert!(r.contains("pool.serving.c16.magazine_cached"), "{r}");
        assert!(r.contains("kv_peak_used"), "{r}");
    }

    #[test]
    fn placement_choice_reaches_the_engine_pool() {
        use crate::pool::{PoolHandle, RoundRobin};
        use std::sync::Arc;
        let e = Engine::with_pool(
            MockBackend::new(),
            EngineConfig::default(),
            PoolHandle::builder().placement(Arc::new(RoundRobin)).build(),
        );
        assert_eq!(e.pool().multi().unwrap().placement_name(), "round_robin");
        let mut d = engine(EngineConfig::default());
        assert_eq!(
            d.pool().multi().unwrap().placement_name(),
            "steal_aware",
            "default serving topology is steal-aware"
        );
        // Maintenance is safe on an idle pool and in system mode.
        d.maintain_pool();
        Engine::with_pool(MockBackend::new(), EngineConfig::default(), PoolHandle::system())
            .maintain_pool();
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        // Run a batch partway, snapshot, and restore into a second engine
        // over a fresh pool: from that point on the two engines must make
        // identical scheduling decisions and emit identical tokens.
        let mut a = engine(EngineConfig { max_batch: 4, ..Default::default() });
        for i in 0..6 {
            a.submit(vec![i + 1, 2 * i + 5], SamplingParams::greedy(12)).unwrap();
        }
        for _ in 0..5 {
            a.step().unwrap();
        }
        let bytes = a.snapshot();
        let mut b = Engine::restore(
            MockBackend::new(),
            crate::pool::PoolHandle::builder().build(),
            &bytes,
        )
        .unwrap();
        assert_eq!(b.steps(), a.steps());
        assert_eq!(b.num_waiting(), a.num_waiting());
        assert_eq!(b.num_running(), a.num_running());
        assert_eq!(b.kv.num_free_blocks(), a.kv.num_free_blocks());
        assert_eq!(b.kv.num_seqs(), a.kv.num_seqs());
        // Lock-step resume: every step produces the same token count.
        while a.has_work() || b.has_work() {
            assert_eq!(a.step().unwrap(), b.step().unwrap());
            assert_eq!(a.steps(), b.steps());
        }
        // Identical outputs, including outputs finished before the
        // snapshot (they travel in the bytes), and identical follow-up
        // ids (next_id travels too).
        let oa = a.take_finished();
        let ob = b.take_finished();
        let dump = |v: &[RequestOutput]| v.iter().map(|o| format!("{o:?}")).collect::<Vec<_>>();
        assert_eq!(dump(&oa), dump(&ob));
        assert_eq!(
            a.submit(vec![1], SamplingParams::greedy(1)).unwrap(),
            b.submit(vec![1], SamplingParams::greedy(1)).unwrap()
        );
        // The restored outputs are the mock's exact continuations.
        for o in &ob {
            if o.id <= 6 {
                assert_eq!(o.finish, FinishReason::Length);
                assert_eq!(o.tokens, mock_expect(&o.prompt, 12), "req {}", o.id);
            }
        }
    }

    #[test]
    fn snapshot_restore_rejects_bad_streams() {
        let mut a = engine(EngineConfig::default());
        a.submit(vec![3, 4], SamplingParams::greedy(4)).unwrap();
        a.step().unwrap();
        let bytes = a.snapshot();
        let pool = || crate::pool::PoolHandle::system();
        // Valid bytes restore fine.
        assert!(Engine::restore(MockBackend::new(), pool(), &bytes).is_ok());
        // Bad magic, truncation, trailing garbage, geometry mismatch.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(
            Engine::restore(MockBackend::new(), pool(), &bad),
            Err(SnapError::BadMagic)
        ));
        assert!(Engine::restore(MockBackend::new(), pool(), &bytes[..bytes.len() - 3]).is_err());
        let mut long = bytes.clone();
        long.push(0);
        assert!(Engine::restore(MockBackend::new(), pool(), &long).is_err());
        assert!(matches!(
            Engine::restore(MockBackend::with_blocks(9, 4, 4), pool(), &bytes),
            Err(SnapError::ConfigMismatch(_))
        ));
    }

    #[test]
    fn decode_survives_mid_run_compaction() {
        // Tiny pool + preemption churn scatters the live KV blocks;
        // compacting between every step rewrites the running sequences'
        // block tables mid-flight. The mock is positional, so outputs
        // must still be the exact uncontended continuations.
        let be = MockBackend::with_blocks(9, 4, 4);
        let mut e = Engine::new(be, EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![i * 3 + 1, i + 2]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(10)).unwrap();
        }
        let mut steps = 0u64;
        while e.has_work() {
            e.step().unwrap();
            let report = e.kv.compact(2);
            assert!(report.post_occupancy >= report.pre_occupancy);
            steps += 1;
            assert!(steps < 100_000, "no completion");
        }
        let mut outs = e.take_finished();
        outs.sort_by_key(|o| o.id);
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
            assert_eq!(o.tokens, mock_expect(p, 10), "req {} across compactions", o.id);
        }
    }

    #[test]
    fn maintain_pool_compacts_sparse_kv_grid() {
        // A finished wave leaves a high watermark with zero live blocks:
        // occupancy 0 < threshold, so maintenance compacts and returns
        // the whole touched span as regions.
        let mut e = engine(EngineConfig { max_batch: 4, ..Default::default() });
        for i in 0..4 {
            // 30-token prompts + 18 generated = 48 tokens = 3 blocks each.
            e.submit(vec![i + 2; 30], SamplingParams::greedy(18)).unwrap();
        }
        e.run_to_completion(100_000).unwrap();
        assert!(e.kv.occupancy() < KV_COMPACT_BELOW);
        e.maintain_pool();
        assert_eq!(e.metrics.counter("kv_compactions").get(), 1);
        assert!(e.metrics.counter("kv_regions_returned").get() >= 1);
        assert_eq!(e.kv.occupancy(), 1.0);
        // Now dense: a second maintenance pass does not compact again.
        e.maintain_pool();
        assert_eq!(e.metrics.counter("kv_compactions").get(), 1);
    }

    #[test]
    fn submit_errors_are_typed() {
        let mut e = engine(EngineConfig { queue_limit: 1, ..Default::default() });
        assert_eq!(
            e.submit(vec![], SamplingParams::greedy(1)),
            Err(SubmitError::EmptyPrompt)
        );
        assert_eq!(
            e.submit(vec![1; 33], SamplingParams::greedy(1)),
            Err(SubmitError::ContextOverflow { len: 33, max: 32 })
        );
        e.submit(vec![1], SamplingParams::greedy(1)).unwrap();
        assert_eq!(
            e.submit(vec![2], SamplingParams::greedy(1)),
            Err(SubmitError::QueueFull { limit: 1 })
        );
        // Strict quota mode: only registered tenants may submit.
        let quotas = TenantQuotas { strict: true, ..Default::default() }.tenant(1, None, None);
        let mut s = engine(EngineConfig { quotas, ..Default::default() });
        assert_eq!(
            s.submit(vec![1], SamplingParams { tenant: 7, ..Default::default() }),
            Err(SubmitError::UnknownTenant { tenant: 7 })
        );
        s.submit(vec![1], SamplingParams { tenant: 1, ..Default::default() }).unwrap();
    }

    #[test]
    fn admission_sheds_before_exhaustion_and_recovers() {
        // 8 data blocks of 4 tokens; each request's worst case is 3
        // blocks (2 prompt + 10 generated = 12 tokens). Committed
        // occupancy per submit: 3/8, 6/8 (≥ low → Queue), 9/8 (≥ high →
        // Reject + latch), latched → Reject.
        let be = MockBackend::with_blocks(9, 4, 4);
        let mut e = Engine::new(
            be,
            EngineConfig {
                max_batch: 4,
                admission_ctl: Some(AdmissionConfig::default()),
                ..Default::default()
            },
        );
        let prompts: Vec<Vec<i32>> = (0..2).map(|i| vec![i * 3 + 1, i + 2]).collect();
        e.submit(prompts[0].clone(), SamplingParams::greedy(10)).unwrap();
        e.submit(prompts[1].clone(), SamplingParams::greedy(10)).unwrap();
        let err = e.submit(vec![9, 9], SamplingParams::greedy(10)).unwrap_err();
        assert!(
            matches!(err, SubmitError::Rejected { retry_after_steps: 64, .. }),
            "{err:?}"
        );
        assert!(e.is_shedding());
        assert!(!e.accepting());
        // Latched: rejected even though nothing changed.
        assert!(e.submit(vec![9], SamplingParams::greedy(1)).is_err());
        assert_eq!(e.metrics.counter("admission_rejected").get(), 2);
        assert_eq!(e.metrics.counter("admission_queued").get(), 1);
        // The admitted pair completes exactly, with zero exhaustion and
        // zero preemption: budget-aware scheduling reserved their worst
        // cases up front.
        let mut outs = e.run_to_completion(10_000).unwrap();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 2);
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.finish, FinishReason::Length);
            assert_eq!(o.tokens, mock_expect(p, 10), "req {}", o.id);
        }
        assert_eq!(e.metrics.counter("pool_exhaustion_events").get(), 0);
        assert_eq!(e.metrics.counter("preemptions").get(), 0);
        // Hysteresis: occupancy fell to 0 < low watermark, so the next
        // submit unlatches and admits.
        e.submit(vec![5, 6], SamplingParams::greedy(10)).unwrap();
        assert!(!e.is_shedding());
        assert!(e.accepting());
    }

    #[test]
    fn queued_admission_expires_to_rejected() {
        // One lane; the second request rides the Queue band with a
        // 2-step deadline it can never make behind a 14-token decode.
        let be = MockBackend::with_blocks(17, 4, 4);
        let mut e = Engine::new(
            be,
            EngineConfig {
                max_batch: 1,
                admission_ctl: Some(AdmissionConfig {
                    high_watermark: 0.9,
                    low_watermark: 0.3,
                    pool_high_watermark: 0.95,
                    max_queue_wait_steps: 2,
                    retry_after_steps: 64,
                }),
                ..Default::default()
            },
        );
        let a = e.submit(vec![1, 2], SamplingParams::greedy(14)).unwrap();
        let b = e.submit(vec![3, 4], SamplingParams::greedy(14)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(e.metrics.counter("admission_queue_timeouts").get(), 1);
        let get = |id| outs.iter().find(|o| o.id == id).unwrap();
        assert_eq!(get(a).finish, FinishReason::Length);
        assert_eq!(get(a).tokens, mock_expect(&[1, 2], 14));
        assert_eq!(get(b).finish, FinishReason::Rejected);
        assert!(get(b).tokens.is_empty());
    }

    #[test]
    fn tenant_hard_quota_rejects_submit() {
        // Default mock: 16-token blocks, so 2 prompt + 30 generated = 2
        // blocks per request. Tenant 1's hard cap of 3 admits one
        // request (committed 2) and rejects the next (committed 4).
        let quotas = TenantQuotas::default().tenant(1, None, Some(3));
        let mut e = engine(EngineConfig { quotas, ..Default::default() });
        let t1 = SamplingParams { max_tokens: 30, tenant: 1, ..Default::default() };
        e.submit(vec![1, 2], t1.clone()).unwrap();
        assert_eq!(
            e.submit(vec![3, 4], t1),
            Err(SubmitError::TenantQuotaExceeded {
                tenant: 1,
                committed_blocks: 4,
                hard_blocks: 3
            })
        );
        assert_eq!(e.metrics.counter("quota_rejected").get(), 1);
        // Other tenants are untouched by tenant 1's cap.
        let t0 = SamplingParams { max_tokens: 30, ..Default::default() };
        e.submit(vec![5, 6], t0).unwrap();
    }

    #[test]
    fn soft_quota_picks_the_over_quota_victim() {
        // Three lock-step requests need 9 blocks of an 8-block pool, so
        // exhaustion preempts exactly one. Tenant 1 (two requests, soft
        // cap 3) is over quota when it hits; its YOUNGEST sequence must
        // be the victim, never tenant 0's.
        let be = MockBackend::with_blocks(9, 4, 4);
        let quotas = TenantQuotas::default().tenant(1, Some(3), None);
        let mut e = Engine::new(be, EngineConfig { max_batch: 4, quotas, ..Default::default() });
        let t1 = SamplingParams { max_tokens: 10, tenant: 1, ..Default::default() };
        let t0 = SamplingParams { max_tokens: 10, ..Default::default() };
        let a = e.submit(vec![1, 2], t1.clone()).unwrap();
        let b = e.submit(vec![3, 4], t1).unwrap();
        let c = e.submit(vec![5, 6], t0).unwrap();
        let outs = e.run_to_completion(100_000).unwrap();
        assert_eq!(outs.len(), 3);
        let get = |id| outs.iter().find(|o| o.id == id).unwrap();
        for (id, p) in [(a, vec![1, 2]), (b, vec![3, 4]), (c, vec![5, 6])] {
            assert_eq!(get(id).finish, FinishReason::Length, "req {id}");
            assert_eq!(get(id).tokens, mock_expect(&p, 10), "req {id}");
        }
        assert!(e.metrics.counter("pool_exhaustion_events").get() >= 1);
        assert_eq!(get(c).preemptions, 0, "tenant 0 must be isolated");
        assert_eq!(get(a).preemptions, 0, "victim is the youngest over-quota seq");
        assert!(get(b).preemptions >= 1);
    }

    #[test]
    fn backend_failures_retry_with_backoff_and_recover() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![3, 4], SamplingParams::greedy(5)).unwrap();
        e.step().unwrap(); // prefill succeeds
        e.backend.fail_next_decodes = 2;
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Length);
        assert_eq!(outs[0].tokens, mock_expect(&[3, 4], 5));
        assert_eq!(e.metrics.counter("backend_errors").get(), 2);
        assert_eq!(e.metrics.counter("backend_decode_errors").get(), 2);
        // Exponential backoff burned idle steps: 1 after the first
        // failure, 2 after the second.
        assert_eq!(e.metrics.counter("backoff_steps").get(), 3);
    }

    #[test]
    fn retry_budget_exhaustion_aborts_cleanly() {
        let mut e = engine(EngineConfig { max_retries: 2, ..Default::default() });
        e.submit(vec![3, 4], SamplingParams::greedy(5)).unwrap();
        e.step().unwrap();
        e.backend.fail_next_decodes = 100;
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Aborted);
        assert!(!e.has_work());
        assert_eq!(e.kv.num_seqs(), 0);
        assert_eq!(e.metrics.counter("backend_errors").get(), 3);
    }

    #[test]
    fn snapshot_v2_carries_admission_and_quota_state() {
        let mk = || MockBackend::with_blocks(9, 4, 4);
        let cfg = EngineConfig {
            max_batch: 4,
            admission_ctl: Some(AdmissionConfig::default()),
            quotas: TenantQuotas::default().tenant(1, Some(6), Some(8)),
            max_retries: 5,
            ..Default::default()
        };
        let mut a = Engine::new(mk(), cfg);
        let t1 = SamplingParams { max_tokens: 10, tenant: 1, ..Default::default() };
        a.submit(vec![1, 2], SamplingParams::greedy(10)).unwrap();
        a.submit(vec![3, 4], t1).unwrap();
        // Third submit latches load shedding (committed 9/8 ≥ high).
        a.submit(vec![5, 6], SamplingParams::greedy(10)).unwrap_err();
        assert!(a.is_shedding());
        for _ in 0..2 {
            a.step().unwrap();
        }
        let bytes = a.snapshot();
        let mut b =
            Engine::restore(mk(), crate::pool::PoolHandle::builder().build(), &bytes).unwrap();
        assert!(b.is_shedding(), "hysteresis latch must survive restore");
        assert_eq!(b.cfg.max_retries, 5);
        assert_eq!(b.cfg.quotas, a.cfg.quotas);
        assert_eq!(b.cfg.admission_ctl, a.cfg.admission_ctl);
        assert_eq!(b.kv.quotas, a.kv.quotas, "quotas re-installed into the KV manager");
        assert_eq!(b.kv.tenant_usage(), a.kv.tenant_usage());
        // Lock-step resume, identical outputs (tenants included).
        while a.has_work() || b.has_work() {
            assert_eq!(a.step().unwrap(), b.step().unwrap());
        }
        let dump = |v: &[RequestOutput]| v.iter().map(|o| format!("{o:?}")).collect::<Vec<_>>();
        assert_eq!(dump(&a.take_finished()), dump(&b.take_finished()));
        // Both engines make the same post-restore admission decision.
        assert_eq!(
            a.submit(vec![7], SamplingParams::greedy(1)),
            b.submit(vec![7], SamplingParams::greedy(1))
        );
        // A v1 stream is no longer accepted.
        let mut old = bytes.clone();
        old[4..8].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(
            Engine::restore(mk(), crate::pool::PoolHandle::system(), &old),
            Err(SnapError::BadVersion(1))
        ));
    }
}
