//! The serving engine: continuous (iteration-level) batching over the
//! paged KV cache, with admission control, optional preemption, and
//! per-request accounting.
//!
//! One `step()` is one scheduler iteration (Orca-style):
//!
//! 1. **Admit**: pull waiting requests (FCFS or SJF) while the block pool
//!    can hold their prompts and the batch has room; run ONE batched
//!    prefill for the admitted set and sample their first tokens.
//! 2. Otherwise **decode**: one batched decode step over all running
//!    sequences (chunked to the compiled batch variants), sample, append.
//! 3. On pool exhaustion mid-decode, **preempt** the youngest running
//!    sequence: free its blocks and requeue it for recompute (its replay
//!    prompt must fit the prefill window, else it aborts).
//!
//! The KV block pool IS the paper's allocator (`kvcache::BlockAllocator`);
//! every admission/append/free on the hot path is an O(1) pool op.

use std::collections::{HashMap, VecDeque};

use super::backend::{Backend, BackendGeometry};
use super::request::{FinishReason, Request, RequestOutput, RequestState, SamplingParams};
use super::sampler;
use crate::kvcache::{CacheError, KvCacheManager};
use crate::metrics::Metrics;

/// Admission policy for prompt blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admit when the prompt's blocks fit — may preempt later.
    Optimistic,
    /// Admit only when a worst-case context (max_blocks_per_seq) fits —
    /// never preempts.
    Conservative,
}

/// Scheduling order for the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    /// Shortest prompt first.
    Sjf,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub queue_limit: usize,
    pub admission: Admission,
    pub policy: Policy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            queue_limit: 256,
            admission: Admission::Optimistic,
            policy: Policy::Fcfs,
        }
    }
}

/// The engine.
pub struct Engine<B: Backend> {
    pub backend: B,
    pub kv: KvCacheManager,
    pub cfg: EngineConfig,
    geo: BackendGeometry,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    reqs: HashMap<u64, Request>,
    finished: Vec<RequestOutput>,
    next_id: u64,
    step_count: u64,
    pub metrics: Metrics,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        let geo = backend.geometry();
        let kv = KvCacheManager::new(
            geo.num_blocks,
            geo.block_tokens,
            geo.max_blocks_per_seq,
        );
        Self {
            backend,
            kv,
            cfg,
            geo,
            waiting: VecDeque::new(),
            running: Vec::new(),
            reqs: HashMap::new(),
            finished: Vec::new(),
            next_id: 1,
            step_count: 0,
            metrics: Metrics::new(),
        }
    }

    /// Submit a request. Fails fast on overload (backpressure) or an
    /// impossible prompt.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams) -> Result<u64, String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if prompt.len() > self.geo.prefill_len {
            return Err(format!(
                "prompt len {} exceeds prefill window {}",
                prompt.len(),
                self.geo.prefill_len
            ));
        }
        if self.waiting.len() >= self.cfg.queue_limit {
            self.metrics.counter("rejected").inc();
            return Err("queue full".into());
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, prompt, params);
        req.arrived_step = self.step_count;
        self.reqs.insert(id, req);
        self.waiting.push_back(id);
        self.metrics.counter("submitted").inc();
        Ok(id)
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// waiting + running (router load balancing).
    pub fn load(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain finished outputs collected so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    pub fn steps(&self) -> u64 {
        self.step_count
    }

    // ------------------------------------------------------------------
    // Scheduling
    // ------------------------------------------------------------------

    /// Pick which waiting requests to admit this step.
    fn plan_admission(&mut self) -> Vec<u64> {
        if self.running.len() >= self.cfg.max_batch {
            return Vec::new();
        }
        // SJF: stable-sort waiting by prompt length.
        if self.cfg.policy == Policy::Sjf {
            let mut ids: Vec<u64> = self.waiting.iter().copied().collect();
            ids.sort_by_key(|id| self.reqs[id].replay_prompt().len());
            self.waiting = ids.into();
        }
        let mut admitted = Vec::new();
        let mut free = self.kv.num_free_blocks() as i64;
        if self.cfg.admission == Admission::Conservative {
            // Reserve worst-case growth for every running sequence so a
            // conservative engine can never hit pool exhaustion.
            let reserved: i64 = self
                .running
                .iter()
                .map(|id| {
                    self.geo.max_blocks_per_seq as i64
                        - self.kv.seq(*id).map(|s| s.blocks.len()).unwrap_or(0) as i64
                })
                .sum();
            free -= reserved;
        }
        let room = self.cfg.max_batch - self.running.len();
        while admitted.len() < room {
            let Some(&id) = self.waiting.front() else { break };
            let prompt_tokens = self.reqs[&id].replay_prompt().len() as u32;
            let needed = match self.cfg.admission {
                Admission::Optimistic => self.kv.blocks_for(prompt_tokens).max(1) as i64,
                Admission::Conservative => self.geo.max_blocks_per_seq as i64,
            };
            if needed > free {
                break; // FCFS head-of-line: wait for blocks
            }
            free -= needed;
            self.waiting.pop_front();
            admitted.push(id);
        }
        admitted
    }

    /// Run one scheduler iteration. Returns the number of tokens produced.
    pub fn step(&mut self) -> Result<usize, String> {
        self.step_count += 1;
        let admitted = self.plan_admission();
        let produced = if !admitted.is_empty() {
            self.do_prefill(admitted)?
        } else if !self.running.is_empty() {
            self.do_decode()?
        } else {
            0
        };
        self.metrics.gauge("running").set(self.running.len() as i64);
        self.metrics.gauge("waiting").set(self.waiting.len() as i64);
        self.metrics
            .gauge("kv_free_blocks")
            .set(self.kv.num_free_blocks() as i64);
        Ok(produced)
    }

    /// Drive until all work completes (or `max_steps`). Returns outputs.
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<Vec<RequestOutput>, String> {
        let mut steps = 0;
        while self.has_work() {
            self.step()?;
            steps += 1;
            if steps > max_steps {
                return Err(format!("no completion after {max_steps} steps"));
            }
        }
        Ok(self.take_finished())
    }

    // ------------------------------------------------------------------
    // Phases
    // ------------------------------------------------------------------

    fn do_prefill(&mut self, admitted: Vec<u64>) -> Result<usize, String> {
        let p = self.geo.prefill_len;
        let mb = self.geo.max_blocks_per_seq;
        let batch = self.geo.pick_batch(admitted.len());
        // Register sequences + build inputs (pad lanes: len 0, scratch table).
        let mut tokens = vec![0i32; batch * p];
        let mut lens = vec![0i32; batch];
        let mut tables = vec![self.geo.scratch_block as i32; batch * mb];
        for (lane, &id) in admitted.iter().enumerate() {
            let replay = self.reqs[&id].replay_prompt();
            self.kv
                .create_seq(id, replay.len() as u32)
                .map_err(|e| format!("admission raced: {e}"))?;
            tokens[lane * p..lane * p + replay.len()].copy_from_slice(&replay);
            lens[lane] = replay.len() as i32;
            tables[lane * mb..(lane + 1) * mb]
                .copy_from_slice(&self.kv.table_row(id).unwrap());
            let req = self.reqs.get_mut(&id).unwrap();
            req.state = RequestState::Running;
            if req.first_scheduled_step.is_none() {
                req.first_scheduled_step = Some(self.step_count);
            }
        }
        let logits = self.backend.prefill(batch, &tokens, &lens, &tables)?;
        self.metrics.counter("prefill_batches").inc();
        // Sample first tokens.
        let v = self.geo.vocab;
        let mut produced = 0;
        for (lane, &id) in admitted.iter().enumerate() {
            let row = &logits[lane * v..(lane + 1) * v];
            let params = self.reqs[&id].params.clone();
            let tok = sampler::sample(row, &params, self.reqs[&id].total_tokens() as u64);
            produced += 1;
            self.running.push(id);
            self.commit_token(id, tok)?;
        }
        Ok(produced)
    }

    fn do_decode(&mut self) -> Result<usize, String> {
        let mb = self.geo.max_blocks_per_seq;
        let ids: Vec<u64> = self.running.clone();
        let mut produced = 0;
        // Chunk the running set into compiled batch variants.
        for chunk in ids.chunks(self.geo.pick_batch(ids.len().min(self.cfg.max_batch))) {
            let batch = self.geo.pick_batch(chunk.len());
            let mut tokens = vec![0i32; batch];
            let mut lens = vec![0i32; batch];
            let mut tables = vec![self.geo.scratch_block as i32; batch * mb];
            for (lane, &id) in chunk.iter().enumerate() {
                let req = &self.reqs[&id];
                // Last token is the most recent generated one (running seqs
                // always have ≥1 generated token, from prefill sampling).
                tokens[lane] = *req.generated.last().expect("running seq has a token");
                // Cache currently holds total_tokens - 1 (the new token's
                // K/V is written by this decode call).
                lens[lane] = (req.total_tokens() - 1) as i32;
                tables[lane * mb..(lane + 1) * mb]
                    .copy_from_slice(&self.kv.table_row(id).unwrap());
            }
            let logits = self.backend.decode(batch, &tokens, &lens, &tables)?;
            self.metrics.counter("decode_batches").inc();
            let v = self.geo.vocab;
            for (lane, &id) in chunk.iter().enumerate() {
                let row = &logits[lane * v..(lane + 1) * v];
                let params = self.reqs[&id].params.clone();
                let tok = sampler::sample(row, &params, self.reqs[&id].total_tokens() as u64);
                produced += 1;
                self.commit_token(id, tok)?;
            }
        }
        Ok(produced)
    }

    /// Append a sampled token: pool accounting, finish detection,
    /// preemption on exhaustion.
    fn commit_token(&mut self, id: u64, tok: i32) -> Result<(), String> {
        // The token's K/V slot: append_token allocates the block if this
        // token crossed a boundary. (The model already wrote K/V into the
        // slot — block ownership was guaranteed by the table row; a fresh
        // block is needed only for the NEXT step's write, so allocating
        // here keeps the table ready before the next decode.)
        let preempted_mid_chunk = {
            let req = &self.reqs[&id];
            req.state == RequestState::Preempted
        };
        let finish = {
            let req = self.reqs.get_mut(&id).unwrap();
            req.push_token(tok)
        };
        if let Some(reason) = finish {
            self.finish(id, reason);
            return Ok(());
        }
        if preempted_mid_chunk {
            // The seq lost its blocks to a preemption earlier in this same
            // chunk; the token (computed before the preemption) is kept in
            // `generated` so the replay prompt stays exact, but there is no
            // cache accounting to do.
            return Ok(());
        }
        match self.kv.append_token(id) {
            Ok(()) => Ok(()),
            Err(CacheError::ContextOverflow) => {
                self.finish(id, FinishReason::ContextOverflow);
                Ok(())
            }
            Err(CacheError::OutOfBlocks { .. }) => {
                self.metrics.counter("pool_exhaustion_events").inc();
                // Preempt the *youngest* running sequence (LIFO) — possibly
                // the one that just overflowed.
                let victim = *self.running.last().unwrap();
                self.preempt(victim);
                if victim != id {
                    // Retry the original append now that blocks are free.
                    match self.kv.append_token(id) {
                        Ok(()) => {}
                        Err(_) => {
                            // Still starved: preempt this one too.
                            self.preempt(id);
                        }
                    }
                }
                Ok(())
            }
            Err(e) => Err(e.to_string()),
        }
    }

    fn preempt(&mut self, id: u64) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        let req = self.reqs.get_mut(&id).unwrap();
        req.preemptions += 1;
        self.metrics.counter("preemptions").inc();
        if req.replay_prompt().len() <= self.geo.prefill_len {
            req.state = RequestState::Preempted;
            self.waiting.push_front(id);
        } else {
            // Cannot recompute through the prefill window.
            self.finish(id, FinishReason::Aborted);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        self.waiting.retain(|&r| r != id); // may finish while preempted
        let mut req = self.reqs.remove(&id).unwrap();
        req.state = RequestState::Finished(reason);
        req.finished_step = Some(self.step_count);
        let first = req.first_scheduled_step.unwrap_or(self.step_count);
        self.metrics.counter("finished").inc();
        self.metrics
            .histogram("queue_steps")
            .record(first.saturating_sub(req.arrived_step));
        self.finished.push(RequestOutput {
            id,
            prompt: req.prompt.clone(),
            tokens: req.generated.clone(),
            finish: reason,
            preemptions: req.preemptions,
            queue_steps: first.saturating_sub(req.arrived_step),
            run_steps: self.step_count.saturating_sub(first),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;

    fn engine(cfg: EngineConfig) -> Engine<MockBackend> {
        Engine::new(MockBackend::new(), cfg)
    }

    /// Expected mock continuation for a prompt.
    fn mock_expect(prompt: &[i32], n: usize) -> Vec<i32> {
        let mut out = Vec::new();
        let mut prev = *prompt.last().unwrap();
        let mut total = prompt.len() as u32;
        for _ in 0..n {
            let t = MockBackend::next_token(prev, total);
            out.push(t);
            prev = t;
            total += 1;
        }
        out
    }

    #[test]
    fn single_request_end_to_end() {
        let mut e = engine(EngineConfig::default());
        let id = e.submit(vec![10, 20, 30], SamplingParams::greedy(6)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, id);
        assert_eq!(outs[0].finish, FinishReason::Length);
        assert_eq!(outs[0].tokens, mock_expect(&[10, 20, 30], 6));
    }

    #[test]
    fn batch_of_requests_all_correct() {
        let mut e = engine(EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> =
            (0..6).map(|i| vec![i + 1, (i + 2) * 3, (i * 7) % 250]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(8)).unwrap();
        }
        let mut outs = e.run_to_completion(10_000).unwrap();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 6);
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.tokens, mock_expect(p, 8), "req {}", o.id);
            assert_eq!(o.finish, FinishReason::Length);
        }
        // All KV blocks returned to the pool.
        assert_eq!(e.kv.num_seqs(), 0);
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn queue_limit_backpressure() {
        let mut e = engine(EngineConfig { queue_limit: 2, ..Default::default() });
        e.submit(vec![1], SamplingParams::greedy(1)).unwrap();
        e.submit(vec![2], SamplingParams::greedy(1)).unwrap();
        assert!(e.submit(vec![3], SamplingParams::greedy(1)).is_err());
        assert_eq!(e.metrics.counter("rejected").get(), 1);
    }

    #[test]
    fn prompt_too_long_rejected() {
        let mut e = engine(EngineConfig::default());
        let long = vec![1i32; 33]; // prefill window is 32
        assert!(e.submit(long, SamplingParams::greedy(1)).is_err());
        assert!(e.submit(vec![1i32; 32], SamplingParams::greedy(1)).is_ok());
    }

    #[test]
    fn eos_stops_early() {
        // Find the first mock token for this prompt and set it as EOS.
        let prompt = vec![5, 6];
        let first = mock_expect(&prompt, 1)[0];
        let mut e = engine(EngineConfig::default());
        e.submit(
            prompt,
            SamplingParams { eos: Some(first), max_tokens: 50, ..Default::default() },
        )
        .unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert_eq!(outs[0].tokens.len(), 1);
    }

    #[test]
    fn context_overflow_finishes_cleanly() {
        // max context = 4 blocks × 16 tokens = 64; prompt 30 + max_tokens
        // 100 would exceed → ContextOverflow.
        let mut e = engine(EngineConfig::default());
        e.submit(vec![9; 30], SamplingParams::greedy(100)).unwrap();
        let outs = e.run_to_completion(10_000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::ContextOverflow);
        // 30 prompt + 34 cached + 1 final uncached token = 35 max.
        assert!(outs[0].tokens.len() <= 35, "{}", outs[0].tokens.len());
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn preemption_recovers_identical_output() {
        // Tiny pool (9 = 8 data + scratch blocks) with long generations
        // forces preemption; the mock's determinism means outputs must be
        // IDENTICAL to an uncontended run.
        let be = MockBackend::with_blocks(9, 4, 4); // blocks of 4 tokens
        let mut e = Engine::new(be, EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![i * 3 + 1, i + 2]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(10)).unwrap();
        }
        let mut outs = e.run_to_completion(100_000).unwrap();
        outs.sort_by_key(|o| o.id);
        let preempted: u32 = outs.iter().map(|o| o.preemptions).sum();
        assert!(preempted > 0, "test should exercise preemption");
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
            assert_eq!(o.tokens, mock_expect(p, 10), "req {} after preemption", o.id);
        }
        assert_eq!(e.metrics.counter("preemptions").get() as u32, preempted);
    }

    #[test]
    fn conservative_admission_never_preempts() {
        let be = MockBackend::with_blocks(9, 4, 4);
        let mut e = Engine::new(
            be,
            EngineConfig {
                max_batch: 4,
                admission: Admission::Conservative,
                ..Default::default()
            },
        );
        for i in 0..4 {
            e.submit(vec![i + 1, i + 5], SamplingParams::greedy(10)).unwrap();
        }
        let outs = e.run_to_completion(100_000).unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(e.metrics.counter("preemptions").get(), 0);
        assert!(outs.iter().all(|o| o.finish == FinishReason::Length));
    }

    #[test]
    fn sjf_schedules_short_prompts_first() {
        let mut e = engine(EngineConfig {
            max_batch: 1,
            policy: Policy::Sjf,
            ..Default::default()
        });
        let long = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let short = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(short) < pos(long), "short prompt should finish first");
    }

    #[test]
    fn fcfs_preserves_order_single_lane() {
        let mut e = engine(EngineConfig { max_batch: 1, ..Default::default() });
        let a = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let b = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(a) < pos(b));
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1, 2], SamplingParams::greedy(3)).unwrap();
        e.run_to_completion(1000).unwrap();
        assert_eq!(e.metrics.counter("submitted").get(), 1);
        assert_eq!(e.metrics.counter("finished").get(), 1);
        assert!(e.metrics.counter("decode_batches").get() >= 1);
        assert!(e.metrics.counter("prefill_batches").get() >= 1);
    }

    #[test]
    fn idle_step_is_noop() {
        let mut e = engine(EngineConfig::default());
        assert_eq!(e.step().unwrap(), 0);
        assert!(!e.has_work());
    }
}
