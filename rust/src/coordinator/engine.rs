//! The serving engine: continuous (iteration-level) batching over the
//! paged KV cache, with admission control, optional preemption, and
//! per-request accounting.
//!
//! One `step()` is one scheduler iteration (Orca-style):
//!
//! 1. **Admit**: pull waiting requests (FCFS or SJF) while the block pool
//!    can hold their prompts and the batch has room; run ONE batched
//!    prefill for the admitted set and sample their first tokens.
//! 2. Otherwise **decode**: one batched decode step over all running
//!    sequences (chunked to the compiled batch variants), sample, append.
//! 3. On pool exhaustion mid-decode, **preempt** the youngest running
//!    sequence: free its blocks and requeue it for recompute (its replay
//!    prompt must fit the prefill window, else it aborts).
//!
//! The KV block pool IS the paper's allocator (`kvcache::BlockAllocator`);
//! every admission/append/free on the hot path is an O(1) pool op.

use std::collections::{HashMap, VecDeque};

use super::backend::{Backend, BackendGeometry};
use super::request::{FinishReason, Request, RequestOutput, RequestState, SamplingParams};
use super::sampler;
use crate::kvcache::{CacheError, KvCacheManager};
use crate::metrics::Metrics;
use crate::pool::{PoolHandle, PooledVec};

/// Admission policy for prompt blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Admit when the prompt's blocks fit — may preempt later.
    Optimistic,
    /// Admit only when a worst-case context (max_blocks_per_seq) fits —
    /// never preempts.
    Conservative,
}

/// Scheduling order for the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    /// Shortest prompt first.
    Sjf,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub queue_limit: usize,
    pub admission: Admission,
    pub policy: Policy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            queue_limit: 256,
            admission: Admission::Optimistic,
            policy: Policy::Fcfs,
        }
    }
}

/// Reusable pool-backed step buffers: sized once from the backend
/// geometry, repainted every iteration, never reallocated in steady
/// state. This is what keeps the decode loop off the system allocator —
/// the per-step `vec![…]`s the loop used to build now live on the
/// engine's [`ShardedMultiPool`](crate::pool::ShardedMultiPool).
struct StepBuffers {
    /// Decode-iteration snapshot of `running` (commit may mutate it).
    ids: PooledVec<u64>,
    tokens: PooledVec<i32>,
    lens: PooledVec<i32>,
    tables: PooledVec<i32>,
    logits: PooledVec<f32>,
}

impl StepBuffers {
    fn new(pool: &PoolHandle, geo: &BackendGeometry, max_batch: usize) -> Self {
        // Lane-indexed buffers are bounded by the largest compiled batch
        // variant (pick_batch never exceeds it); the ids snapshot by the
        // scheduler's own batch cap.
        let max_b = geo.batch_sizes.iter().copied().max().unwrap_or(1).max(max_batch);
        // The logits buffer is write-only to the engine (every Backend
        // fully overwrites `batch * vocab`): paint it once here so the
        // per-step resize is a pure length change, no memset.
        let mut logits = PooledVec::with_capacity(pool, max_b * geo.vocab);
        logits.fill_with(max_b * geo.vocab, 0.0);
        Self {
            ids: PooledVec::with_capacity(pool, max_b),
            tokens: PooledVec::with_capacity(pool, max_b * geo.prefill_len),
            lens: PooledVec::with_capacity(pool, max_b),
            tables: PooledVec::with_capacity(pool, max_b * geo.max_blocks_per_seq),
            logits,
        }
    }
}

/// The engine.
pub struct Engine<B: Backend> {
    pub backend: B,
    pub kv: KvCacheManager,
    pub cfg: EngineConfig,
    geo: BackendGeometry,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    reqs: HashMap<u64, Request>,
    finished: Vec<RequestOutput>,
    next_id: u64,
    step_count: u64,
    /// Allocation capability for the request/KV hot path; shared with the
    /// KV manager and the step buffers.
    pool: PoolHandle,
    bufs: StepBuffers,
    pub metrics: Metrics,
}

impl<B: Backend> Engine<B> {
    /// Pool-backed engine (the default): per-request and per-step
    /// allocations ride a shared [`crate::pool::ShardedMultiPool`].
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        Self::with_pool(backend, cfg, PoolHandle::builder().build())
    }

    /// Engine over an explicit allocation handle. Pass
    /// [`PoolHandle::system`] for the malloc-backed ablation arm (A4) —
    /// identical engine code, no pool.
    pub fn with_pool(backend: B, cfg: EngineConfig, pool: PoolHandle) -> Self {
        let geo = backend.geometry();
        let kv = KvCacheManager::with_pool(
            geo.num_blocks,
            geo.block_tokens,
            geo.max_blocks_per_seq,
            pool.clone(),
        );
        let bufs = StepBuffers::new(&pool, &geo, cfg.max_batch);
        Self {
            backend,
            kv,
            cfg,
            geo,
            waiting: VecDeque::new(),
            running: Vec::new(),
            reqs: HashMap::new(),
            finished: Vec::new(),
            next_id: 1,
            step_count: 0,
            pool,
            bufs,
            metrics: Metrics::new(),
        }
    }

    /// The engine's allocation handle (shared with the KV manager).
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Publish the serving pool's per-class and per-shard hit/steal
    /// gauges into this engine's metrics registry — the payload of the
    /// server's periodic stats dump.
    pub fn export_pool_metrics(&self) {
        if let Some(mp) = self.pool.multi() {
            mp.export_metrics(&self.metrics, "pool.serving");
        }
        self.metrics.gauge("kv_peak_used").set(self.kv.peak_used as i64);
    }

    /// Periodic pool maintenance (the server runs it with the stats
    /// dump): return steal-stash blocks — including chains orphaned by
    /// exited worker threads — to their owning shards' free lists, and
    /// flush idle magazines (per-thread caches whose owner has exited)
    /// back to the shared tiers, recording how many blocks moved.
    /// Allocation-free; a no-op in system mode.
    pub fn maintain_pool(&self) {
        if let Some(mp) = self.pool.multi() {
            let drained = mp.drain_stashes();
            if drained > 0 {
                self.metrics.counter("pool_stash_drained").add(drained as u64);
            }
            let flushed = mp.flush_stale_magazines();
            if flushed > 0 {
                self.metrics.counter("pool_magazines_flushed").add(flushed as u64);
            }
        }
    }

    /// Submit a request. Fails fast on overload (backpressure) or an
    /// impossible prompt.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams) -> Result<u64, String> {
        if prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if prompt.len() > self.geo.prefill_len {
            return Err(format!(
                "prompt len {} exceeds prefill window {}",
                prompt.len(),
                self.geo.prefill_len
            ));
        }
        if self.waiting.len() >= self.cfg.queue_limit {
            self.metrics.counter("rejected").inc();
            return Err("queue full".into());
        }
        // Clamp the generation budget to the model's context window:
        // generation can never exceed it (ContextOverflow fires first), and
        // `Request::new` reserves `max_tokens` up front — an unclamped
        // client value (e.g. u32::MAX over the wire) must not turn into a
        // multi-GiB reservation.
        let mut params = params;
        params.max_tokens = params.max_tokens.min(self.geo.max_context());
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, prompt, params);
        req.arrived_step = self.step_count;
        self.reqs.insert(id, req);
        self.waiting.push_back(id);
        self.metrics.counter("submitted").inc();
        Ok(id)
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Can another request enter the waiting queue right now? (The
    /// router's capacity-aware failover checks this before routing.)
    pub fn has_queue_capacity(&self) -> bool {
        self.waiting.len() < self.cfg.queue_limit
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    /// waiting + running (router load balancing).
    pub fn load(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain finished outputs collected so far.
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    pub fn steps(&self) -> u64 {
        self.step_count
    }

    // ------------------------------------------------------------------
    // Scheduling
    // ------------------------------------------------------------------

    /// Pick which waiting requests to admit this step.
    fn plan_admission(&mut self) -> Vec<u64> {
        if self.running.len() >= self.cfg.max_batch {
            return Vec::new();
        }
        // SJF: stable-sort waiting by prompt length.
        if self.cfg.policy == Policy::Sjf {
            let mut ids: Vec<u64> = self.waiting.iter().copied().collect();
            ids.sort_by_key(|id| self.reqs[id].replay_prompt().len());
            self.waiting = ids.into();
        }
        let mut admitted = Vec::new();
        let mut free = self.kv.num_free_blocks() as i64;
        if self.cfg.admission == Admission::Conservative {
            // Reserve worst-case growth for every running sequence so a
            // conservative engine can never hit pool exhaustion.
            let reserved: i64 = self
                .running
                .iter()
                .map(|id| {
                    self.geo.max_blocks_per_seq as i64
                        - self.kv.seq(*id).map(|s| s.blocks.len()).unwrap_or(0) as i64
                })
                .sum();
            free -= reserved;
        }
        let room = self.cfg.max_batch - self.running.len();
        while admitted.len() < room {
            let Some(&id) = self.waiting.front() else { break };
            let prompt_tokens = self.reqs[&id].replay_prompt().len() as u32;
            let needed = match self.cfg.admission {
                Admission::Optimistic => self.kv.blocks_for(prompt_tokens).max(1) as i64,
                Admission::Conservative => self.geo.max_blocks_per_seq as i64,
            };
            if needed > free {
                break; // FCFS head-of-line: wait for blocks
            }
            free -= needed;
            self.waiting.pop_front();
            admitted.push(id);
        }
        admitted
    }

    /// Run one scheduler iteration. Returns the number of tokens produced.
    pub fn step(&mut self) -> Result<usize, String> {
        self.step_count += 1;
        let admitted = self.plan_admission();
        let produced = if !admitted.is_empty() {
            self.do_prefill(admitted)?
        } else if !self.running.is_empty() {
            self.do_decode()?
        } else {
            0
        };
        self.metrics.gauge("running").set(self.running.len() as i64);
        self.metrics.gauge("waiting").set(self.waiting.len() as i64);
        self.metrics
            .gauge("kv_free_blocks")
            .set(self.kv.num_free_blocks() as i64);
        Ok(produced)
    }

    /// Drive until all work completes (or `max_steps`). Returns outputs.
    ///
    /// `max_steps` is an exact budget — at most `max_steps` calls to
    /// [`Self::step`] — matching `Router::run_to_completion` (both used
    /// to burn one extra step before erroring).
    pub fn run_to_completion(&mut self, max_steps: u64) -> Result<Vec<RequestOutput>, String> {
        let mut steps = 0;
        while self.has_work() {
            if steps == max_steps {
                return Err(format!("no completion after {max_steps} steps"));
            }
            self.step()?;
            steps += 1;
        }
        Ok(self.take_finished())
    }

    // ------------------------------------------------------------------
    // Phases
    // ------------------------------------------------------------------

    fn do_prefill(&mut self, admitted: Vec<u64>) -> Result<usize, String> {
        let p = self.geo.prefill_len;
        let mb = self.geo.max_blocks_per_seq;
        let v = self.geo.vocab;
        let batch = self.geo.pick_batch(admitted.len());
        // Register sequences + repaint the step buffers (pad lanes: len 0,
        // scratch table). The buffers are pool-backed and reused.
        self.bufs.tokens.fill_with(batch * p, 0);
        self.bufs.lens.fill_with(batch, 0);
        self.bufs.tables.fill_with(batch * mb, self.geo.scratch_block as i32);
        self.bufs.logits.set_len_initialized(batch * v);
        for (lane, &id) in admitted.iter().enumerate() {
            let replay = self.reqs[&id].replay_prompt();
            self.kv
                .create_seq(id, replay.len() as u32)
                .map_err(|e| format!("admission raced: {e}"))?;
            self.bufs.tokens[lane * p..lane * p + replay.len()].copy_from_slice(&replay);
            self.bufs.lens[lane] = replay.len() as i32;
            self.kv
                .table_row_into(id, &mut self.bufs.tables[lane * mb..(lane + 1) * mb])
                .unwrap();
            let req = self.reqs.get_mut(&id).unwrap();
            req.state = RequestState::Running;
            if req.first_scheduled_step.is_none() {
                req.first_scheduled_step = Some(self.step_count);
            }
        }
        self.backend.prefill(
            batch,
            &self.bufs.tokens,
            &self.bufs.lens,
            &self.bufs.tables,
            &mut self.bufs.logits,
        )?;
        self.metrics.counter("prefill_batches").inc();
        // Sample first tokens.
        let mut produced = 0;
        for (lane, &id) in admitted.iter().enumerate() {
            let tok = {
                let req = &self.reqs[&id];
                let row = &self.bufs.logits[lane * v..(lane + 1) * v];
                sampler::sample(row, &req.params, req.total_tokens() as u64)
            };
            produced += 1;
            self.running.push(id);
            self.commit_token(id, tok)?;
        }
        Ok(produced)
    }

    fn do_decode(&mut self) -> Result<usize, String> {
        // Snapshot the running set into the reusable ids buffer — commit
        // may preempt/finish entries mid-iteration, so we must not walk
        // `self.running` directly (this replaces the per-step clone).
        let mut ids = std::mem::take(&mut self.bufs.ids);
        ids.clear();
        ids.extend_from_slice(&self.running);
        let res = self.decode_ids(&ids);
        self.bufs.ids = ids;
        res
    }

    fn decode_ids(&mut self, ids: &[u64]) -> Result<usize, String> {
        let mb = self.geo.max_blocks_per_seq;
        let v = self.geo.vocab;
        let mut produced = 0;
        // Chunk the running set into compiled batch variants.
        for chunk in ids.chunks(self.geo.pick_batch(ids.len().min(self.cfg.max_batch))) {
            let batch = self.geo.pick_batch(chunk.len());
            self.bufs.tokens.fill_with(batch, 0);
            self.bufs.lens.fill_with(batch, 0);
            self.bufs.tables.fill_with(batch * mb, self.geo.scratch_block as i32);
            self.bufs.logits.set_len_initialized(batch * v);
            for (lane, &id) in chunk.iter().enumerate() {
                // A request can vanish (aborted) or lose its cache rows
                // (preempted) through an earlier chunk's preemption
                // cascade; decoding such a lane would attend over the
                // scratch block and commit a garbage token into its
                // replay prompt. Leave it a pad lane — `lens == 0` marks
                // it, and the sampling loop below skips those (a live
                // lane always has lens ≥ 1: non-empty prompt + ≥1
                // generated token).
                let Some(req) = self.reqs.get(&id) else { continue };
                if req.state != RequestState::Running {
                    continue;
                }
                // Last token is the most recent generated one (running seqs
                // always have ≥1 generated token, from prefill sampling).
                self.bufs.tokens[lane] =
                    *req.generated.last().expect("running seq has a token");
                // Cache currently holds total_tokens - 1 (the new token's
                // K/V is written by this decode call).
                self.bufs.lens[lane] = (req.total_tokens() - 1) as i32;
                self.kv
                    .table_row_into(id, &mut self.bufs.tables[lane * mb..(lane + 1) * mb])
                    .expect("running request has a cache row");
            }
            self.backend.decode(
                batch,
                &self.bufs.tokens,
                &self.bufs.lens,
                &self.bufs.tables,
                &mut self.bufs.logits,
            )?;
            self.metrics.counter("decode_batches").inc();
            for (lane, &id) in chunk.iter().enumerate() {
                // Pad lane (vanished or preempted before this chunk was
                // painted): nothing was decoded for it, nothing to commit.
                // Requests preempted mid-chunk (after painting) keep their
                // lens ≥ 1 lane and still commit, preserving the exact
                // replay prompt.
                if self.bufs.lens[lane] == 0 {
                    continue;
                }
                let tok = {
                    let Some(req) = self.reqs.get(&id) else { continue };
                    let row = &self.bufs.logits[lane * v..(lane + 1) * v];
                    sampler::sample(row, &req.params, req.total_tokens() as u64)
                };
                produced += 1;
                self.commit_token(id, tok)?;
            }
        }
        Ok(produced)
    }

    /// Append a sampled token: pool accounting, finish detection,
    /// preemption on exhaustion.
    fn commit_token(&mut self, id: u64, tok: i32) -> Result<(), String> {
        // The token's K/V slot: append_token allocates the block if this
        // token crossed a boundary. (The model already wrote K/V into the
        // slot — block ownership was guaranteed by the table row; a fresh
        // block is needed only for the NEXT step's write, so allocating
        // here keeps the table ready before the next decode.)
        let preempted_mid_chunk = {
            let req = &self.reqs[&id];
            req.state == RequestState::Preempted
        };
        let finish = {
            let req = self.reqs.get_mut(&id).unwrap();
            req.push_token(tok)
        };
        if let Some(reason) = finish {
            self.finish(id, reason);
            return Ok(());
        }
        if preempted_mid_chunk {
            // The seq lost its blocks to a preemption earlier in this same
            // chunk; the token (computed before the preemption) is kept in
            // `generated` so the replay prompt stays exact, but there is no
            // cache accounting to do.
            return Ok(());
        }
        match self.kv.append_token(id) {
            Ok(()) => Ok(()),
            Err(CacheError::ContextOverflow) => {
                self.finish(id, FinishReason::ContextOverflow);
                Ok(())
            }
            Err(CacheError::OutOfBlocks { .. }) => {
                self.metrics.counter("pool_exhaustion_events").inc();
                // Preempt the *youngest* running sequence (LIFO) — possibly
                // the one that just overflowed.
                let victim = *self.running.last().unwrap();
                self.preempt(victim);
                if victim != id {
                    // Retry the original append now that blocks are free.
                    match self.kv.append_token(id) {
                        Ok(()) => {}
                        Err(_) => {
                            // Still starved: preempt this one too.
                            self.preempt(id);
                        }
                    }
                }
                Ok(())
            }
            Err(e) => Err(e.to_string()),
        }
    }

    fn preempt(&mut self, id: u64) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        let req = self.reqs.get_mut(&id).unwrap();
        req.preemptions += 1;
        self.metrics.counter("preemptions").inc();
        if req.replay_prompt().len() <= self.geo.prefill_len {
            req.state = RequestState::Preempted;
            self.waiting.push_front(id);
        } else {
            // Cannot recompute through the prefill window.
            self.finish(id, FinishReason::Aborted);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let _ = self.kv.free_seq(id);
        self.running.retain(|&r| r != id);
        self.waiting.retain(|&r| r != id); // may finish while preempted
        let mut req = self.reqs.remove(&id).unwrap();
        req.state = RequestState::Finished(reason);
        req.finished_step = Some(self.step_count);
        let first = req.first_scheduled_step.unwrap_or(self.step_count);
        self.metrics.counter("finished").inc();
        self.metrics
            .histogram("queue_steps")
            .record(first.saturating_sub(req.arrived_step));
        // The request is dead: move its buffers into the output instead of
        // cloning them.
        self.finished.push(RequestOutput {
            id,
            prompt: req.prompt,
            tokens: req.generated,
            finish: reason,
            preemptions: req.preemptions,
            queue_steps: first.saturating_sub(req.arrived_step),
            run_steps: self.step_count.saturating_sub(first),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;

    fn engine(cfg: EngineConfig) -> Engine<MockBackend> {
        Engine::new(MockBackend::new(), cfg)
    }

    /// Expected mock continuation for a prompt.
    fn mock_expect(prompt: &[i32], n: usize) -> Vec<i32> {
        let mut out = Vec::new();
        let mut prev = *prompt.last().unwrap();
        let mut total = prompt.len() as u32;
        for _ in 0..n {
            let t = MockBackend::next_token(prev, total);
            out.push(t);
            prev = t;
            total += 1;
        }
        out
    }

    #[test]
    fn single_request_end_to_end() {
        let mut e = engine(EngineConfig::default());
        let id = e.submit(vec![10, 20, 30], SamplingParams::greedy(6)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].id, id);
        assert_eq!(outs[0].finish, FinishReason::Length);
        assert_eq!(outs[0].tokens, mock_expect(&[10, 20, 30], 6));
    }

    #[test]
    fn batch_of_requests_all_correct() {
        let mut e = engine(EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> =
            (0..6).map(|i| vec![i + 1, (i + 2) * 3, (i * 7) % 250]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(8)).unwrap();
        }
        let mut outs = e.run_to_completion(10_000).unwrap();
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 6);
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.tokens, mock_expect(p, 8), "req {}", o.id);
            assert_eq!(o.finish, FinishReason::Length);
        }
        // All KV blocks returned to the pool.
        assert_eq!(e.kv.num_seqs(), 0);
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn queue_limit_backpressure() {
        let mut e = engine(EngineConfig { queue_limit: 2, ..Default::default() });
        e.submit(vec![1], SamplingParams::greedy(1)).unwrap();
        e.submit(vec![2], SamplingParams::greedy(1)).unwrap();
        assert!(e.submit(vec![3], SamplingParams::greedy(1)).is_err());
        assert_eq!(e.metrics.counter("rejected").get(), 1);
    }

    #[test]
    fn prompt_too_long_rejected() {
        let mut e = engine(EngineConfig::default());
        let long = vec![1i32; 33]; // prefill window is 32
        assert!(e.submit(long, SamplingParams::greedy(1)).is_err());
        assert!(e.submit(vec![1i32; 32], SamplingParams::greedy(1)).is_ok());
    }

    #[test]
    fn eos_stops_early() {
        // Find the first mock token for this prompt and set it as EOS.
        let prompt = vec![5, 6];
        let first = mock_expect(&prompt, 1)[0];
        let mut e = engine(EngineConfig::default());
        e.submit(
            prompt,
            SamplingParams { eos: Some(first), max_tokens: 50, ..Default::default() },
        )
        .unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert_eq!(outs[0].tokens.len(), 1);
    }

    #[test]
    fn context_overflow_finishes_cleanly() {
        // max context = 4 blocks × 16 tokens = 64; prompt 30 + max_tokens
        // 100 would exceed → ContextOverflow.
        let mut e = engine(EngineConfig::default());
        e.submit(vec![9; 30], SamplingParams::greedy(100)).unwrap();
        let outs = e.run_to_completion(10_000).unwrap();
        assert_eq!(outs[0].finish, FinishReason::ContextOverflow);
        // 30 prompt + 34 cached + 1 final uncached token = 35 max.
        assert!(outs[0].tokens.len() <= 35, "{}", outs[0].tokens.len());
        assert_eq!(e.kv.num_free_blocks(), e.backend.geo.num_blocks - 1);
    }

    #[test]
    fn preemption_recovers_identical_output() {
        // Tiny pool (9 = 8 data + scratch blocks) with long generations
        // forces preemption; the mock's determinism means outputs must be
        // IDENTICAL to an uncontended run.
        let be = MockBackend::with_blocks(9, 4, 4); // blocks of 4 tokens
        let mut e = Engine::new(be, EngineConfig { max_batch: 4, ..Default::default() });
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![i * 3 + 1, i + 2]).collect();
        for p in &prompts {
            e.submit(p.clone(), SamplingParams::greedy(10)).unwrap();
        }
        let mut outs = e.run_to_completion(100_000).unwrap();
        outs.sort_by_key(|o| o.id);
        let preempted: u32 = outs.iter().map(|o| o.preemptions).sum();
        assert!(preempted > 0, "test should exercise preemption");
        for (o, p) in outs.iter().zip(&prompts) {
            assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
            assert_eq!(o.tokens, mock_expect(p, 10), "req {} after preemption", o.id);
        }
        assert_eq!(e.metrics.counter("preemptions").get() as u32, preempted);
    }

    #[test]
    fn conservative_admission_never_preempts() {
        let be = MockBackend::with_blocks(9, 4, 4);
        let mut e = Engine::new(
            be,
            EngineConfig {
                max_batch: 4,
                admission: Admission::Conservative,
                ..Default::default()
            },
        );
        for i in 0..4 {
            e.submit(vec![i + 1, i + 5], SamplingParams::greedy(10)).unwrap();
        }
        let outs = e.run_to_completion(100_000).unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(e.metrics.counter("preemptions").get(), 0);
        assert!(outs.iter().all(|o| o.finish == FinishReason::Length));
    }

    #[test]
    fn sjf_schedules_short_prompts_first() {
        let mut e = engine(EngineConfig {
            max_batch: 1,
            policy: Policy::Sjf,
            ..Default::default()
        });
        let long = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let short = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(short) < pos(long), "short prompt should finish first");
    }

    #[test]
    fn fcfs_preserves_order_single_lane() {
        let mut e = engine(EngineConfig { max_batch: 1, ..Default::default() });
        let a = e.submit(vec![1; 20], SamplingParams::greedy(1)).unwrap();
        let b = e.submit(vec![2; 2], SamplingParams::greedy(1)).unwrap();
        let outs = e.run_to_completion(1000).unwrap();
        let pos = |id| outs.iter().position(|o| o.id == id).unwrap();
        assert!(pos(a) < pos(b));
    }

    #[test]
    fn metrics_populated() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1, 2], SamplingParams::greedy(3)).unwrap();
        e.run_to_completion(1000).unwrap();
        assert_eq!(e.metrics.counter("submitted").get(), 1);
        assert_eq!(e.metrics.counter("finished").get(), 1);
        assert!(e.metrics.counter("decode_batches").get() >= 1);
        assert!(e.metrics.counter("prefill_batches").get() >= 1);
    }

    #[test]
    fn run_to_completion_budget_is_exact() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1], SamplingParams::greedy(50)).unwrap();
        let err = e.run_to_completion(3).unwrap_err();
        assert!(err.contains("after 3 steps"), "{err}");
        assert_eq!(e.steps(), 3, "budget is exact, not max_steps + 1");
    }

    #[test]
    fn idle_step_is_noop() {
        let mut e = engine(EngineConfig::default());
        assert_eq!(e.step().unwrap(), 0);
        assert!(!e.has_work());
    }

    #[test]
    fn pool_backed_and_malloc_backed_agree() {
        // A4's correctness leg: the two ablation arms run identical
        // engine code and must produce identical outputs.
        let run = |pool: crate::pool::PoolHandle| {
            let mut e = Engine::with_pool(
                MockBackend::new(),
                EngineConfig { max_batch: 4, ..Default::default() },
                pool,
            );
            for i in 0..6 {
                e.submit(vec![i + 1, 2 * i + 3], SamplingParams::greedy(12)).unwrap();
            }
            let mut outs = e.run_to_completion(100_000).unwrap();
            outs.sort_by_key(|o| o.id);
            outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
        };
        let pooled = run(crate::pool::PoolHandle::builder().build());
        let malloc = run(crate::pool::PoolHandle::system());
        assert_eq!(pooled, malloc);
    }

    #[test]
    fn pool_serves_the_steady_state_hot_path() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![1, 2, 3], SamplingParams::greedy(20)).unwrap();
        e.run_to_completion(10_000).unwrap();
        let mp = e.pool().multi().expect("default engine is pool-backed");
        let hits: u64 = (0..mp.num_classes()).map(|c| mp.class_hits(c)).sum();
        assert!(hits > 0, "step buffers and KV tables must be pool-served");
        assert!(mp.pool_hit_rate() > 0.9, "{}", mp.pool_hit_rate());
        // The serving arm runs in cached mode: the same workload must
        // have ridden the per-thread magazines.
        assert!(mp.magazines_enabled(), "serving pool defaults to cached mode");
        let ms = mp.magazine_stats();
        assert!(
            ms.hits + ms.refills > 0,
            "request/KV allocations must ride the magazine layer: {ms:?}"
        );
    }

    #[test]
    fn export_pool_metrics_publishes_gauges() {
        let mut e = engine(EngineConfig::default());
        e.submit(vec![4, 5], SamplingParams::greedy(4)).unwrap();
        e.run_to_completion(1000).unwrap();
        e.export_pool_metrics();
        let r = e.metrics.report();
        assert!(r.contains("pool.serving.hit_rate_pct"), "{r}");
        assert!(r.contains("pool.serving.c16.shards"), "{r}");
        assert!(r.contains("pool.serving.rehomes_total"), "{r}");
        assert!(r.contains("pool.serving.c16.local_hit_pct"), "{r}");
        assert!(r.contains("pool.serving.magazine_hits_total"), "{r}");
        assert!(r.contains("pool.serving.magazine_refills_total"), "{r}");
        assert!(r.contains("pool.serving.c16.magazine_cached"), "{r}");
        assert!(r.contains("kv_peak_used"), "{r}");
    }

    #[test]
    fn placement_choice_reaches_the_engine_pool() {
        use crate::pool::{PoolHandle, RoundRobin};
        use std::sync::Arc;
        let e = Engine::with_pool(
            MockBackend::new(),
            EngineConfig::default(),
            PoolHandle::builder().placement(Arc::new(RoundRobin)).build(),
        );
        assert_eq!(e.pool().multi().unwrap().placement_name(), "round_robin");
        let d = engine(EngineConfig::default());
        assert_eq!(
            d.pool().multi().unwrap().placement_name(),
            "steal_aware",
            "default serving topology is steal-aware"
        );
        // Maintenance is safe on an idle pool and in system mode.
        d.maintain_pool();
        Engine::with_pool(MockBackend::new(), EngineConfig::default(), PoolHandle::system())
            .maintain_pool();
    }
}
