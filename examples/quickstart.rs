//! Quickstart: the paper's fixed-size pool in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the §IV algorithm step by step (the Figure-2 example), then shows
//! the typed RAII layer, the overhead accounting (§I "no overhead"), and a
//! first speed taste against malloc.

use fastpool::alloc::{PoolAllocator, SystemAllocator};
use fastpool::pool::{FixedPool, PoolConfig, TypedPool};
use fastpool::util::{fmt_ns, Timer};
use fastpool::workload::{patterns, replay};

fn main() {
    banner("1. the paper's algorithm, step by step (Figure 2)");
    let mut pool = FixedPool::new(PoolConfig::new(8, 4));
    println!("created 4x8B pool: watermark={}, free={}  (creation touched 0 blocks)",
        pool.raw().num_initialized(), pool.num_free());

    let a = pool.allocate().unwrap();
    println!("alloc -> block {} | watermark={} free={}",
        pool.raw().index_from_addr(a), pool.raw().num_initialized(), pool.num_free());
    let b = pool.allocate().unwrap();
    println!("alloc -> block {} | watermark={} free={}",
        pool.raw().index_from_addr(b), pool.raw().num_initialized(), pool.num_free());
    // SAFETY: `a` came from `allocate` and is freed exactly once.
    unsafe { pool.deallocate(a) };
    println!("free block 0     | head of in-band free list is block 0 again");
    let c = pool.allocate().unwrap();
    println!("alloc -> block {} (LIFO reuse, O(1), no loops)", pool.raw().index_from_addr(c));

    banner("2. typed pool: ctor/dtor discipline for free (§V)");
    #[derive(Debug)]
    struct Particle {
        pos: [f32; 3],
        vel: [f32; 3],
        life: f32,
    }
    let particles: TypedPool<Particle> = TypedPool::new(1024);
    let p = particles
        .alloc(Particle { pos: [0.0; 3], vel: [1.0, 2.0, 0.5], life: 1.0 })
        .ok()
        .unwrap();
    println!("allocated {p:?}");
    println!("live={} free={}", particles.live(), particles.free());
    drop(p); // destructor runs, block returns — no manual bookkeeping
    println!("after drop: live={} free={}", particles.live(), particles.free());

    banner("3. overhead accounting (§I \"little memory footprint\")");
    let big = FixedPool::with_blocks(256, 1_000_000);
    let s = big.stats();
    println!("pool: 1M x 256B = {} MiB managed", s.capacity_bytes / (1 << 20));
    println!(
        "bookkeeping: {} bytes total = {:.6} bytes/block = {:.8}% of capacity",
        s.header_overhead_bytes,
        s.overhead_per_block(),
        s.overhead_ratio() * 100.0
    );

    banner("4. first taste of the speedup (Figure 4 preview)");
    let trace = patterns::alloc_then_free_all(10_000, 64);
    let mut malloc = SystemAllocator::new();
    let mut pool = PoolAllocator::new(64, 10_000);
    // Warm both once, measure second run.
    replay(&trace, &mut malloc);
    replay(&trace, &mut pool);
    let rm = replay(&trace, &mut malloc);
    let rp = replay(&trace, &mut pool);
    println!("10k alloc+free of 64B:");
    println!("  malloc: {:>10} ({:.1} ns/op)", fmt_ns(rm.total_ns as f64), rm.ns_per_op());
    println!("  pool:   {:>10} ({:.1} ns/op)", fmt_ns(rp.total_ns as f64), rp.ns_per_op());
    println!("  speedup: {:.1}x  (full sweep: cargo bench)", rm.ns_per_op() / rp.ns_per_op());

    banner("5. creation cost: lazy vs the naive loop (§I)");
    for n in [1_000u32, 100_000, 10_000_000] {
        let t = Timer::start();
        let lazy = FixedPool::with_blocks(64, n);
        let lazy_ns = t.elapsed_ns();
        let t = Timer::start();
        let eager = fastpool::pool::EagerPool::with_blocks(64, n);
        let eager_ns = t.elapsed_ns();
        println!(
            "n={n:>9}: lazy create {} | eager create {} ({:>6.1}x)",
            fmt_ns(lazy_ns as f64),
            fmt_ns(eager_ns as f64),
            eager_ns as f64 / lazy_ns.max(1) as f64
        );
        drop(lazy);
        drop(eager);
    }
}

fn banner(s: &str) {
    println!("\n=== {s} ===");
}
