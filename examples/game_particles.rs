//! Game-loop example (§I's motivating domain): a frame-based particle /
//! packet / asset simulation where every allocation goes through fixed
//! pools sized per category, compared live against malloc.
//!
//! ```bash
//! cargo run --release --example game_particles
//! ```

use fastpool::alloc::{BenchAllocator, PoolAllocator, SystemAllocator};
use fastpool::util::{fmt_ns, LogHistogram, Timer};
use fastpool::workload::game::{generate, GameConfig};
use fastpool::workload::{replay, Op};

fn main() {
    let cfg = GameConfig { frames: 1200, particles_per_frame: 40.0, ..Default::default() };
    let (trace, stats) = generate(cfg, 7);
    println!("generated game trace: {} ops over {} frames", trace.ops.len(), cfg.frames);
    println!(
        "  particles: {} allocs (peak {}), packets: {} (peak {}), assets: {} (peak {})",
        stats.particle_allocs,
        stats.peak_particles,
        stats.packet_allocs,
        stats.peak_packets,
        stats.asset_allocs,
        stats.peak_assets
    );

    // Category pools sized at 2x observed peaks (a real game knows these
    // numbers — "sizes of these resources can be determined prior", §I).
    let mut particle_pool = PoolAllocator::new(cfg.particle_size as usize, stats.peak_particles * 2);
    let mut packet_pool = PoolAllocator::new(cfg.packet_size as usize, stats.peak_packets * 2 + 8);
    let mut asset_pool = PoolAllocator::new(cfg.asset_size as usize, stats.peak_assets * 2 + 4);
    let mut malloc = SystemAllocator::new();

    // Frame-time comparison: replay the trace routing by size category.
    let run = |route_to_pools: bool,
               particle_pool: &mut PoolAllocator,
               packet_pool: &mut PoolAllocator,
               asset_pool: &mut PoolAllocator,
               malloc: &mut SystemAllocator| {
        let mut live: std::collections::HashMap<u32, (fastpool::alloc::AllocHandle, u8)> =
            std::collections::HashMap::new();
        let mut frame_hist = LogHistogram::new();
        let t_all = Timer::start();
        let mut ops_in_frame = 0;
        let mut t_frame = Timer::start();
        for op in &trace.ops {
            match *op {
                Op::Alloc { id, size } => {
                    let (h, cat) = if route_to_pools {
                        if size == cfg.particle_size {
                            (particle_pool.alloc(size as usize), 0u8)
                        } else if size == cfg.packet_size {
                            (packet_pool.alloc(size as usize), 1)
                        } else {
                            (asset_pool.alloc(size as usize), 2)
                        }
                    } else {
                        (malloc.alloc(size as usize), 3)
                    };
                    if let Some(h) = h {
                        live.insert(id, (h, cat));
                    }
                }
                Op::Free { id } => {
                    if let Some((h, cat)) = live.remove(&id) {
                        match cat {
                            0 => particle_pool.free(h),
                            1 => packet_pool.free(h),
                            2 => asset_pool.free(h),
                            _ => malloc.free(h),
                        }
                    }
                }
            }
            ops_in_frame += 1;
            // ~trace.ops.len()/frames ops per frame → sample frame times.
            if ops_in_frame >= trace.ops.len() / cfg.frames as usize {
                frame_hist.record(t_frame.elapsed_ns());
                t_frame = Timer::start();
                ops_in_frame = 0;
            }
        }
        for (_, (h, cat)) in live.drain() {
            match cat {
                0 => particle_pool.free(h),
                1 => packet_pool.free(h),
                2 => asset_pool.free(h),
                _ => malloc.free(h),
            }
        }
        (t_all.elapsed_ns(), frame_hist)
    };

    // Warm-up + measure.
    for label in ["malloc", "pools "] {
        let pools = label == "pools ";
        let _ = run(pools, &mut particle_pool, &mut packet_pool, &mut asset_pool, &mut malloc);
        let (total, hist) = run(pools, &mut particle_pool, &mut packet_pool, &mut asset_pool, &mut malloc);
        println!(
            "{label}: total {} | alloc-path per frame p50 {} p99 {} max {}",
            fmt_ns(total as f64),
            fmt_ns(hist.percentile(50.0) as f64),
            fmt_ns(hist.percentile(99.0) as f64),
            fmt_ns(hist.max() as f64),
        );
    }

    // The paper's headline, restated for games: deterministic frame cost.
    println!("\npool stats after run:");
    println!("  particles: {}", particle_pool.pool().stats().report());
    println!("  packets:   {}", packet_pool.pool().stats().report());
    println!("  assets:    {}", asset_pool.pool().stats().report());

    // Sanity: a straight replay through the generic driver agrees.
    let mut p = PoolAllocator::new(cfg.asset_size as usize, trace.peak_live + 16);
    let r = replay(&trace, &mut p);
    println!(
        "\n(one-pool replay: {} ops in {}, {:.1} ns/op, {} failed)",
        r.ops,
        fmt_ns(r.total_ns as f64),
        r.ns_per_op(),
        r.failed_allocs
    );
}
