//! §V "overloading new and delete": the pool family as the program's
//! `#[global_allocator]`. Every `Box`, `Vec`, `String` under 4 KiB in this
//! process is served by lock-free fixed pools with system fallback.
//!
//! ```bash
//! cargo run --release --example custom_global_alloc
//! ```

use fastpool::pool::PooledGlobalAlloc;
use fastpool::util::Timer;

#[global_allocator]
static GLOBAL: PooledGlobalAlloc = PooledGlobalAlloc::new(131_072);

fn main() {
    // Ordinary Rust code — no pool API in sight.
    let t = Timer::start();
    let mut strings: Vec<String> = Vec::new();
    for i in 0..100_000 {
        strings.push(format!("request-{i}"));
        if i % 3 == 0 {
            strings.swap_remove(i / 3 % strings.len().max(1));
        }
    }
    let mut maps = Vec::new();
    for i in 0..1000 {
        let mut m = std::collections::HashMap::new();
        for j in 0..50 {
            m.insert(j, vec![i as u8; 100]);
        }
        maps.push(m);
    }
    drop(maps);
    let total = strings.iter().map(|s| s.len()).sum::<usize>();
    let elapsed = t.elapsed_secs();

    let (pool_hits, system) = GLOBAL.stats();
    println!("did ordinary Vec/String/HashMap work: {total} bytes live, {elapsed:.3}s");
    println!("global allocator stats:");
    println!("  served from pools:  {pool_hits}");
    println!("  system fallbacks:   {system}");
    println!(
        "  pool share:         {:.1}%",
        100.0 * pool_hits as f64 / (pool_hits + system).max(1) as f64
    );
    assert!(pool_hits > system, "pools should serve the majority of small allocs");
}
