//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (L1 Pallas paged-attention kernel inside the L2
//! JAX transformer, compiled to HLO text), serves a Poisson-arrival batch
//! of text prompts through the L3 continuous-batching engine whose KV
//! blocks are managed by the paper's fixed-size pool algorithm, and
//! reports latency/throughput + pool accounting.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_transformer
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §A8.

use fastpool::coordinator::{
    tokenizer, Engine, EngineConfig, Policy, SamplingParams, XlaBackend,
};
use fastpool::runtime::Runtime;
use fastpool::util::{fmt_ns, LogHistogram, Rng, Timer};

const PROMPTS: &[&str] = &[
    "the quick brown fox",
    "memory pools are",
    "fixed size blocks",
    "no loops and",
    "allocate and free",
    "paged attention reads",
    "games need fast",
    "packets arrive in bursts",
    "assets stream from disk",
    "the free list lives",
    "inside the unused blocks",
    "constant time always",
];

fn main() -> Result<(), String> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading + compiling artifacts from {dir}/ ...");
    let t = Timer::start();
    let rt = Runtime::load(&dir)?;
    println!(
        "  {} executables in {:.1}s | model: {} params, {} layers, vocab {}",
        rt.names().len(),
        t.elapsed_secs(),
        rt.meta.num_params,
        rt.meta.n_layers,
        rt.meta.vocab
    );
    println!(
        "  kv pool: {} blocks x {} tokens (scratch block {})",
        rt.meta.num_blocks, rt.meta.block_tokens, rt.meta.scratch_block
    );

    let backend = XlaBackend::new(rt)?;
    let mut engine = Engine::new(
        backend,
        EngineConfig { max_batch: 4, policy: Policy::Fcfs, ..Default::default() },
    );

    // Workload: 24 requests with varied prompts and decode lengths,
    // arriving in 3 waves (tests continuous batching + admission).
    let mut rng = Rng::new(2024);
    let n_requests = 24;
    let mut submitted = Vec::new();
    let mut latency = LogHistogram::new();
    let wall = Timer::start();
    let mut arrivals: Vec<(u64, usize)> = (0..n_requests)
        .map(|i| (rng.gen_range(3), i)) // wave 0..2
        .collect();
    arrivals.sort_unstable();

    let mut wave = 0u64;
    let mut produced_tokens = 0usize;
    let mut outputs = Vec::new();
    let mut queued: std::collections::HashMap<u64, Timer> = Default::default();
    let mut next = 0usize;
    while outputs.len() < n_requests {
        // Admit this wave's arrivals.
        while next < arrivals.len() && arrivals[next].0 <= wave {
            let i = arrivals[next].1;
            let text = PROMPTS[i % PROMPTS.len()];
            let mut prompt = tokenizer::encode(text);
            prompt.truncate(31);
            let max_tokens = 8 + rng.gen_range(24) as u32;
            let id = engine.submit(prompt, SamplingParams::greedy(max_tokens))?;
            queued.insert(id, Timer::start());
            submitted.push((id, text, max_tokens));
            next += 1;
        }
        engine.step()?;
        produced_tokens += 0; // counted from outputs below
        for o in engine.take_finished() {
            if let Some(t) = queued.remove(&o.id) {
                latency.record(t.elapsed_ns());
            }
            produced_tokens += o.tokens.len();
            outputs.push(o);
        }
        wave += 1;
        if wave > 1_000_000 {
            return Err("did not converge".into());
        }
    }
    let secs = wall.elapsed_secs();

    println!("\n== end-to-end serving report ==");
    println!("requests:         {n_requests} (3 arrival waves)");
    println!("tokens generated: {produced_tokens} in {secs:.2}s");
    println!("throughput:       {:.1} tok/s | {:.2} req/s", produced_tokens as f64 / secs, n_requests as f64 / secs);
    println!(
        "request latency:  p50 {} | p95 {} | max {}",
        fmt_ns(latency.percentile(50.0) as f64),
        fmt_ns(latency.percentile(95.0) as f64),
        fmt_ns(latency.max() as f64)
    );
    println!(
        "model time:       {} across {} prefill + {} decode calls",
        fmt_ns(engine.backend.model_ns as f64),
        engine.backend.prefill_calls,
        engine.backend.decode_calls
    );
    println!(
        "engine overhead:  {:.1}% of wall outside PJRT",
        100.0 * (1.0 - engine.backend.model_ns as f64 / (secs * 1e9))
    );
    println!(
        "kv pool:          peak {} blocks used, {} free at end, {} preemptions",
        engine.kv.peak_used,
        engine.kv.num_free_blocks(),
        engine.metrics.counter("preemptions").get()
    );

    println!("\nsample generations:");
    outputs.sort_by_key(|o| o.id);
    for o in outputs.iter().take(4) {
        println!(
            "  [{}] {:?} -> {:?} ({:?})",
            o.id,
            tokenizer::decode(&o.prompt),
            tokenizer::decode(&o.tokens),
            o.finish
        );
    }

    // Invariant: pool fully drained.
    assert_eq!(engine.kv.num_seqs(), 0);
    println!("\nOK: all sequences completed, all KV blocks returned to the pool");
    Ok(())
}
