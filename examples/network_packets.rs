//! Network-packet example: MTU-sized buffers from a lock-free pool shared
//! by producer and consumer threads (§VI's threading limitation, solved by
//! `AtomicPool`), the same pipeline on the sharded pool (per-thread shard
//! hints, per-shard hit/steal metrics), plus the ad-hoc `MultiPool` for
//! odd-sized control messages (§V).
//!
//! ```bash
//! cargo run --release --example network_packets
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use fastpool::metrics::Metrics;
use fastpool::pool::{AtomicPool, MultiPool, MultiPoolConfig, Origin, ShardedPool};
use fastpool::util::{fmt_rate, Rng, Timer};

const MTU: usize = 1536;
const RING: usize = 1024;

fn main() {
    println!("=== lock-free packet pool: 2 producers, 2 consumers ===");
    let pool = Arc::new(AtomicPool::with_blocks(MTU, 4096));
    let (tx, rx) = std::sync::mpsc::sync_channel::<u32>(RING);
    let rx = Arc::new(std::sync::Mutex::new(rx));
    let stop = Arc::new(AtomicBool::new(false));
    let sent = Arc::new(AtomicU64::new(0));
    let received = Arc::new(AtomicU64::new(0));

    let t = Timer::start();
    std::thread::scope(|s| {
        // Producers: "receive" packets off the wire into pool buffers.
        for prod in 0..2u64 {
            let pool = Arc::clone(&pool);
            let tx = tx.clone();
            let stop = Arc::clone(&stop);
            let sent = Arc::clone(&sent);
            s.spawn(move || {
                let mut rng = Rng::new(prod + 1);
                while !stop.load(Ordering::Relaxed) {
                    if let Some(idx) = pool.allocate_index() {
                        // Fill a header + payload.
                        // SAFETY: `idx` is a block this producer exclusively owns until it is
                        // sent; the slice covers exactly the MTU-sized block.
                        let p = unsafe {
                            std::slice::from_raw_parts_mut(
                                pool_ptr(&pool, idx),
                                MTU,
                            )
                        };
                        let len = 64 + rng.gen_usize(0, MTU - 64);
                        p[0..8].copy_from_slice(&(len as u64).to_le_bytes());
                        p[8] = prod as u8;
                        if tx.send(idx).is_err() {
                            pool.deallocate_index(idx);
                            break;
                        }
                        sent.fetch_add(1, Ordering::Relaxed);
                    } else {
                        std::hint::spin_loop(); // pool exhausted: backpressure
                    }
                }
            });
        }
        // Consumers: process and return buffers.
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            let rx = Arc::clone(&rx);
            let stop = Arc::clone(&stop);
            let received = Arc::clone(&received);
            s.spawn(move || {
                loop {
                    let idx = {
                        let guard = rx.lock().unwrap();
                        guard.recv_timeout(std::time::Duration::from_millis(50))
                    };
                    match idx {
                        Ok(idx) => {
                            // SAFETY: the consumer owns `idx` once received; the block is MTU bytes.
                            let p = unsafe {
                                std::slice::from_raw_parts(pool_ptr(&pool, idx), MTU)
                            };
                            let len = u64::from_le_bytes(p[0..8].try_into().unwrap());
                            assert!(len as usize <= MTU, "corrupt packet");
                            pool.deallocate_index(idx);
                            received.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            if stop.load(Ordering::Relaxed) {
                                break;
                            }
                        }
                    }
                }
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(500));
        stop.store(true, Ordering::Relaxed);
        drop(tx);
    });
    // Shutdown race: a producer that read `stop == false` can still send
    // after every consumer timed out and exited — drain those stragglers
    // so the leak assert below only fires on real leaks.
    while let Ok(idx) = rx.lock().unwrap().try_recv() {
        pool.deallocate_index(idx);
    }
    let secs = t.elapsed_secs();
    let n = received.load(Ordering::Relaxed);
    println!(
        "processed {} packets in {:.2}s = {} | pool free at end: {}/{}",
        n,
        secs,
        fmt_rate(n as f64 / secs),
        pool.num_free(),
        pool.num_blocks()
    );
    assert_eq!(pool.num_free(), pool.num_blocks(), "buffer leak!");

    println!("\n=== sharded packet pool: 4 producers, 4 consumers ===");
    // Same pipeline, but each thread's allocations hit its home shard —
    // the single CAS head stops being the bottleneck at higher thread
    // counts, and the steal counters show how often routing crossed shards.
    let spool = Arc::new(ShardedPool::with_shards(MTU, 4096, 8));
    let (stx, srx) = std::sync::mpsc::sync_channel::<usize>(RING);
    let srx = Arc::new(std::sync::Mutex::new(srx));
    let sstop = Arc::new(AtomicBool::new(false));
    let sreceived = Arc::new(AtomicU64::new(0));

    let t = Timer::start();
    std::thread::scope(|s| {
        for prod in 0..4u64 {
            let spool = Arc::clone(&spool);
            let stx = stx.clone();
            let sstop = Arc::clone(&sstop);
            s.spawn(move || {
                let mut rng = Rng::new(prod + 11);
                while !sstop.load(Ordering::Relaxed) {
                    if let Some(ptr) = spool.allocate() {
                        // SAFETY: `ptr` is an exclusively-owned MTU-sized block from `allocate`.
                        let p = unsafe { std::slice::from_raw_parts_mut(ptr.as_ptr(), MTU) };
                        let len = 64 + rng.gen_usize(0, MTU - 64);
                        p[0..8].copy_from_slice(&(len as u64).to_le_bytes());
                        p[8] = prod as u8;
                        if stx.send(ptr.as_ptr() as usize).is_err() {
                            // SAFETY: the send failed, so ownership stays here; freed exactly once.
                            unsafe { spool.deallocate(ptr) };
                            break;
                        }
                    } else {
                        std::hint::spin_loop(); // exhausted: backpressure
                    }
                }
            });
        }
        for _ in 0..4 {
            let spool = Arc::clone(&spool);
            let srx = Arc::clone(&srx);
            let sstop = Arc::clone(&sstop);
            let sreceived = Arc::clone(&sreceived);
            s.spawn(move || loop {
                let addr = {
                    let guard = srx.lock().unwrap();
                    guard.recv_timeout(std::time::Duration::from_millis(50))
                };
                match addr {
                    Ok(addr) => {
                        let ptr = std::ptr::NonNull::new(addr as *mut u8).unwrap();
                        // SAFETY: the consumer owns the block once its address is received;
                        // the block is MTU bytes.
                        let p = unsafe { std::slice::from_raw_parts(ptr.as_ptr(), MTU) };
                        let len = u64::from_le_bytes(p[0..8].try_into().unwrap());
                        assert!(len as usize <= MTU, "corrupt packet");
                        // O(1) free: the owning shard is decoded from the
                        // pointer offset (no shard id travels with the packet).
                        // SAFETY: the consumer owns the block and frees it exactly once.
                        unsafe { spool.deallocate(ptr) };
                        sreceived.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        if sstop.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                }
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(500));
        sstop.store(true, Ordering::Relaxed);
        drop(stx);
    });
    // Same shutdown-race drain as the atomic arm above.
    while let Ok(addr) = srx.lock().unwrap().try_recv() {
        // SAFETY: the drain owns every address still in the channel; each
        // block is freed exactly once.
        unsafe { spool.deallocate(std::ptr::NonNull::new(addr as *mut u8).unwrap()) };
    }
    let secs = t.elapsed_secs();
    let n = sreceived.load(Ordering::Relaxed);
    println!(
        "processed {} packets in {:.2}s = {} | pool free at end: {}/{}",
        n,
        secs,
        fmt_rate(n as f64 / secs),
        spool.num_free(),
        spool.num_blocks()
    );
    assert_eq!(spool.num_free(), spool.num_blocks(), "buffer leak!");
    println!("shard accounting: {}", spool.stats().report());
    let metrics = Metrics::new();
    spool.export_metrics(&metrics, "pool.packets");
    print!("{}", metrics.report());

    println!("\n=== ad-hoc multi-pool for control messages (§V) ===");
    let mut mp = MultiPool::new(MultiPoolConfig {
        min_class: 16,
        max_class: 2048,
        blocks_per_class: 512,
        system_fallback: true,
        magazine_depth: 0, // MultiPool is single-threaded: no magazines
        ..Default::default()
    });
    let mut rng = Rng::new(99);
    let mut live = Vec::new();
    for _ in 0..20_000 {
        if live.is_empty() || rng.gen_bool(0.5) {
            // Control messages: zipf-ish sizes, occasional jumbo.
            let size = if rng.gen_bool(0.02) {
                4096 + rng.gen_usize(0, 8192)
            } else {
                8 + rng.gen_usize(0, 512)
            };
            if let Some((p, o)) = mp.allocate(size) {
                live.push((p, size, o));
            }
        } else {
            let i = rng.gen_usize(0, live.len());
            // Frees resolve the serving class from the pointer alone.
            let (p, size, _o) = live.swap_remove(i);
            // SAFETY: `(p, size)` came from `allocate(size)` and was removed from
            // `live`, so it is freed exactly once.
            unsafe { mp.deallocate(p, size) };
        }
    }
    let pooled = live.iter().filter(|(_, _, o)| matches!(o, Origin::Pool(_))).count();
    println!(
        "live at end: {} ({} pooled) | pool hit rate {:.1}% | internal waste {} KiB | system fallbacks {} | cross-class spills {}",
        live.len(),
        pooled,
        mp.pool_hit_rate() * 100.0,
        mp.total_internal_waste() / 1024,
        mp.system_allocs,
        mp.spill_total()
    );
    for (p, size, _o) in live.drain(..) {
        // SAFETY: the remaining live blocks were never freed in the loop above.
        unsafe { mp.deallocate(p, size) };
    }
    println!("drained cleanly");
}

fn pool_ptr(pool: &AtomicPool, idx: u32) -> *mut u8 {
    (pool.region_start() + idx as usize * pool.block_size()) as *mut u8
}
