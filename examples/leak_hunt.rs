//! §IV.B in action: hunting leaks, overruns, and double frees with
//! `GuardedPool` — and walking the exact live set through the traversal
//! API, from a single guarded pool up to the serving `PoolHandle`
//! lineage — then measuring what the checks cost (the debug/release
//! trade-off the paper quantifies with Figures 3 vs 4).
//!
//! Every "leak report" here is asserted, not just printed: the traversed
//! live set must match what the workload actually left allocated.
//!
//! ```bash
//! cargo run --release --example leak_hunt
//! ```

use fastpool::pool::{
    FixedPool, GuardConfig, GuardError, GuardedPool, PoolHandle, PooledVec,
};
use fastpool::util::{fmt_ns, Timer};

fn main() {
    println!("=== 1. leak report with tags (\"the line number of the allocation\") ===");
    let mut pool = GuardedPool::with_blocks(64, 32, GuardConfig::default());
    let _a = pool.allocate("asset_loader.rs:101").unwrap();
    let b = pool.allocate("particle_system.rs:55").unwrap();
    let _c = pool.allocate("net/session.rs:310").unwrap();
    pool.deallocate(b).unwrap();
    // The report rides the traversal API now: the free-chain complement
    // must yield exactly the two blocks the workload never freed.
    let leaks = pool.leaks();
    assert_eq!(leaks.len(), 2, "exactly the two unfreed blocks leak");
    assert_eq!(pool.num_live(), 2);
    let tags: Vec<&str> = leaks.iter().map(|l| l.tag).collect();
    assert_eq!(
        tags,
        ["asset_loader.rs:101", "net/session.rs:310"],
        "leak report is ordered by allocation seq"
    );
    println!("live allocations at shutdown (leaks):");
    for leak in &leaks {
        println!("  block {:>3}  seq {:>3}  tag {}", leak.index, leak.seq, leak.tag);
    }

    println!("\n=== 2. buffer overrun caught by the post-canary ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::default());
    let p = pool.allocate("overrun.rs:1").unwrap();
    // SAFETY: the 17th byte lands in the slot's post-guard area — still
    // inside pool memory, deliberately clobbering the canary.
    unsafe {
        // Write 17 bytes into a 16-byte block — classic off-by-one.
        std::ptr::write_bytes(p.as_ptr(), 0xAB, 17);
    }
    match pool.deallocate(p) {
        Err(GuardError::PostCanaryClobbered { index, found }) => {
            println!("  caught: block {index} post-canary = {found:#018x}");
        }
        other => println!("  unexpected: {other:?}"),
    }

    println!("\n=== 3. double free caught by the allocation bitmap ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::default());
    let p = pool.allocate("df.rs:2").unwrap();
    pool.deallocate(p).unwrap();
    match pool.deallocate(p) {
        Err(GuardError::NotAllocated) => println!("  caught: double free"),
        other => println!("  unexpected: {other:?}"),
    }

    println!("\n=== 4. global sweep catches corruption of a LIVE block ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::paranoid());
    let victim = pool.allocate("live.rs:3").unwrap();
    let _ok = pool.allocate("live.rs:4").unwrap();
    // SAFETY: `add(16)` lands in the post-guard area — inside pool memory.
    let guard = unsafe { victim.as_ptr().add(16) };
    // SAFETY: the post-guard byte is pool memory; clobbering it is the point.
    unsafe { guard.write(0xFF) };
    match pool.check_all() {
        Err(e) => println!("  caught by global sweep: {e}"),
        Ok(()) => println!("  MISSED (should not happen)"),
    }

    println!("\n=== 5. exact live set through the serving handle (builder + traversal) ===");
    // The builder replaces the deprecated PoolHandle constructor zoo.
    let handle = PoolHandle::builder()
        .classes([64usize, 256])
        .blocks_per_class(128)
        .build();
    assert_eq!(handle.live_count(), 0, "fresh pool has no live blocks");
    let v1: PooledVec<u8> = PooledVec::with_capacity(&handle, 64); // 64B class
    let v2: PooledVec<u64> = PooledVec::with_capacity(&handle, 32); // 256B class
    let v3: PooledVec<u8> = PooledVec::with_capacity(&handle, 200); // 256B class
    assert_eq!(handle.live_count(), 3);
    {
        // Pin the pool for a concurrent-safe walk (allocation parks while
        // the pin is held — so don't allocate from it in this scope).
        let _pin = handle.pin_for_traversal();
        let mut per_class = [0u32; 2];
        handle.for_each_live(|blk| per_class[blk.class] += 1);
        assert_eq!(per_class, [1, 2], "one 64B block live, two 256B blocks live");
    }
    drop(v2);
    // The dropped table's block now sits in this thread's magazine: cached
    // blocks are FREE, not live — the traversal must not report it.
    assert_eq!(handle.live_count(), 2, "magazine-cached block left the live set");
    println!(
        "  live after drop(v2): {} (its block is magazine-cached → free, not live)",
        handle.live_count()
    );
    drop(v1);
    drop(v3);
    assert_eq!(handle.live_count(), 0, "everything returned: no leaks");
    println!("  all tables dropped: live set is empty");

    println!("\n=== 6. what do the checks cost? (§IV.B \"at the cost of\") ===");
    const N: u32 = 100_000;
    let cost = |label: &str, cfg: Option<GuardConfig>| {
        let t = Timer::start();
        match cfg {
            Some(cfg) => {
                let mut p = GuardedPool::with_blocks(64, 1024, cfg);
                for _ in 0..N {
                    let h = p.allocate("bench").unwrap();
                    p.deallocate(h).unwrap();
                }
            }
            None => {
                let mut p = FixedPool::with_blocks(64, 1024);
                for _ in 0..N {
                    let h = p.allocate().unwrap();
                    // SAFETY: `h` came from `allocate` and is freed exactly once.
                    unsafe { p.deallocate(h) };
                }
            }
        }
        let ns = t.elapsed_ns() as f64 / (N as f64);
        println!("  {label:<26} {:>10}/pair", fmt_ns(ns));
        ns
    };
    let raw = cost("raw pool (release)", None);
    let off = cost("guarded, checks off", Some(GuardConfig::off()));
    let def = cost("guarded, default checks", Some(GuardConfig::default()));
    let par = cost("guarded, paranoid+sweeps", Some(GuardConfig::paranoid()));
    println!(
        "  → overhead: wrapper {:.1}x, default {:.1}x, paranoid {:.1}x vs raw",
        off / raw,
        def / raw,
        par / raw
    );
}
