//! §IV.B in action: hunting leaks, overruns, and double frees with
//! `GuardedPool` — then measuring what the checks cost (the debug/release
//! trade-off the paper quantifies with Figures 3 vs 4).
//!
//! ```bash
//! cargo run --release --example leak_hunt
//! ```

use fastpool::pool::{FixedPool, GuardConfig, GuardError, GuardedPool};
use fastpool::util::{fmt_ns, Timer};

fn main() {
    println!("=== 1. leak report with tags (\"the line number of the allocation\") ===");
    let mut pool = GuardedPool::with_blocks(64, 32, GuardConfig::default());
    let _a = pool.allocate("asset_loader.rs:101").unwrap();
    let b = pool.allocate("particle_system.rs:55").unwrap();
    let _c = pool.allocate("net/session.rs:310").unwrap();
    pool.deallocate(b).unwrap();
    println!("live allocations at shutdown (leaks):");
    for leak in pool.leaks() {
        println!("  block {:>3}  seq {:>3}  tag {}", leak.index, leak.seq, leak.tag);
    }

    println!("\n=== 2. buffer overrun caught by the post-canary ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::default());
    let p = pool.allocate("overrun.rs:1").unwrap();
    // SAFETY: the 17th byte lands in the slot's post-guard area — still
    // inside pool memory, deliberately clobbering the canary.
    unsafe {
        // Write 17 bytes into a 16-byte block — classic off-by-one.
        std::ptr::write_bytes(p.as_ptr(), 0xAB, 17);
    }
    match pool.deallocate(p) {
        Err(GuardError::PostCanaryClobbered { index, found }) => {
            println!("  caught: block {index} post-canary = {found:#018x}");
        }
        other => println!("  unexpected: {other:?}"),
    }

    println!("\n=== 3. double free caught by the allocation bitmap ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::default());
    let p = pool.allocate("df.rs:2").unwrap();
    pool.deallocate(p).unwrap();
    match pool.deallocate(p) {
        Err(GuardError::NotAllocated) => println!("  caught: double free"),
        other => println!("  unexpected: {other:?}"),
    }

    println!("\n=== 4. global sweep catches corruption of a LIVE block ===");
    let mut pool = GuardedPool::with_blocks(16, 8, GuardConfig::paranoid());
    let victim = pool.allocate("live.rs:3").unwrap();
    let _ok = pool.allocate("live.rs:4").unwrap();
    // SAFETY: `add(16)` lands in the post-guard area — inside pool memory.
    let guard = unsafe { victim.as_ptr().add(16) };
    // SAFETY: the post-guard byte is pool memory; clobbering it is the point.
    unsafe { guard.write(0xFF) };
    match pool.check_all() {
        Err(e) => println!("  caught by global sweep: {e}"),
        Ok(()) => println!("  MISSED (should not happen)"),
    }

    println!("\n=== 5. what do the checks cost? (§IV.B \"at the cost of\") ===");
    const N: u32 = 100_000;
    let cost = |label: &str, cfg: Option<GuardConfig>| {
        let t = Timer::start();
        match cfg {
            Some(cfg) => {
                let mut p = GuardedPool::with_blocks(64, 1024, cfg);
                for _ in 0..N {
                    let h = p.allocate("bench").unwrap();
                    p.deallocate(h).unwrap();
                }
            }
            None => {
                let mut p = FixedPool::with_blocks(64, 1024);
                for _ in 0..N {
                    let h = p.allocate().unwrap();
                    // SAFETY: `h` came from `allocate` and is freed exactly once.
                    unsafe { p.deallocate(h) };
                }
            }
        }
        let ns = t.elapsed_ns() as f64 / (N as f64);
        println!("  {label:<26} {:>10}/pair", fmt_ns(ns));
        ns
    };
    let raw = cost("raw pool (release)", None);
    let off = cost("guarded, checks off", Some(GuardConfig::off()));
    let def = cost("guarded, default checks", Some(GuardConfig::default()));
    let par = cost("guarded, paranoid+sweeps", Some(GuardConfig::paranoid()));
    println!(
        "  → overhead: wrapper {:.1}x, default {:.1}x, paranoid {:.1}x vs raw",
        off / raw,
        def / raw,
        par / raw
    );
}
