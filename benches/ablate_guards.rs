//! **Ablation A4** — what §IV.B's verification costs: raw pool vs guarded
//! pool at increasing paranoia vs the simulated debug heap.
//!
//! Run: `cargo bench --bench ablate_guards`

use fastpool::alloc::{DebugHeapAllocator, DebugLevel};
use fastpool::alloc::BenchAllocator;
use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::pool::{FixedPool, GuardConfig, GuardedPool};
use fastpool::util::Timer;

const N: u32 = 200_000;
const BLOCK: usize = 64;
const LIVE: u32 = 256; // steady live set while churning

fn churn_guarded(cfg: GuardConfig) -> f64 {
    let mut pool = GuardedPool::with_blocks(BLOCK, LIVE * 2, cfg);
    let mut live = Vec::with_capacity(LIVE as usize);
    for _ in 0..LIVE {
        live.push(pool.allocate("bench").unwrap());
    }
    let t = Timer::start();
    for i in 0..N {
        let idx = (i as usize * 7919) % live.len();
        let p = live.swap_remove(idx);
        pool.deallocate(p).unwrap();
        live.push(pool.allocate("bench").unwrap());
    }
    let ns = t.elapsed_ns() as f64 / N as f64;
    for p in live {
        pool.deallocate(p).unwrap();
    }
    ns
}

fn churn_raw() -> f64 {
    let mut pool = FixedPool::with_blocks(BLOCK, LIVE * 2);
    let mut live = Vec::with_capacity(LIVE as usize);
    for _ in 0..LIVE {
        live.push(pool.allocate().unwrap());
    }
    let t = Timer::start();
    for i in 0..N {
        let idx = (i as usize * 7919) % live.len();
        let p = live.swap_remove(idx);
        // SAFETY: `p` came from `allocate` and was removed from `live`, so it
        // is freed exactly once.
        unsafe { pool.deallocate(p) };
        live.push(pool.allocate().unwrap());
    }
    let ns = t.elapsed_ns() as f64 / N as f64;
    for p in live {
        // SAFETY: the remaining live pointers were never freed in the loop above.
        unsafe { pool.deallocate(p) };
    }
    ns
}

fn churn_debug_heap(level: DebugLevel) -> f64 {
    let mut heap = DebugHeapAllocator::new(level);
    let mut live = Vec::with_capacity(LIVE as usize);
    for _ in 0..LIVE {
        live.push(heap.alloc(BLOCK).unwrap());
    }
    // Full sweeps are O(live) per op — scale op count down and report per-op.
    let n = if level == DebugLevel::Full { N / 50 } else { N };
    let t = Timer::start();
    for i in 0..n {
        let idx = (i as usize * 7919) % live.len();
        let h = live.swap_remove(idx);
        heap.free(h);
        live.push(heap.alloc(BLOCK).unwrap());
    }
    let ns = t.elapsed_ns() as f64 / n as f64;
    for h in live {
        heap.free(h);
    }
    ns
}

fn main() {
    let suite = Suite::new("guards");
    let configs: Vec<(&str, Box<dyn Fn() -> f64>)> = vec![
        ("pool raw (release)", Box::new(churn_raw)),
        ("guarded: off", Box::new(|| churn_guarded(GuardConfig::off()))),
        (
            "guarded: canaries only",
            Box::new(|| {
                churn_guarded(GuardConfig {
                    canaries: true,
                    fills: false,
                    track_double_free: false,
                    sweep_every: 0,
                })
            }),
        ),
        ("guarded: default", Box::new(|| churn_guarded(GuardConfig::default()))),
        ("guarded: paranoid", Box::new(|| churn_guarded(GuardConfig::paranoid()))),
        ("debug heap (light)", Box::new(|| churn_debug_heap(DebugLevel::Light))),
        ("debug heap (debugger)", Box::new(|| churn_debug_heap(DebugLevel::Full))),
    ];

    let mut tab = ReportTable::new(
        "A4: verification cost ladder (steady churn, 256 live x 64B)",
        "configuration",
        configs.iter().map(|(n, _)| n.to_string()).collect(),
        vec!["ns/pair".into(), "x vs raw".into()],
        "ns per alloc+free pair (median of 7)",
    );

    let mut raw_ns = None;
    for (ri, (name, f)) in configs.iter().enumerate() {
        if !suite.enabled(name) {
            continue;
        }
        let mut xs: Vec<f64> = (0..7).map(|_| f()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        if ri == 0 {
            raw_ns = Some(med);
        }
        let ratio = raw_ns.map(|r| med / r).unwrap_or(f64::NAN);
        println!("{name:<24} {med:>9.1} ns/pair  ({ratio:>7.1}x raw)");
        tab.set(ri, 0, med);
        tab.set(ri, 1, ratio);
    }

    println!("\n== A4 summary ==");
    println!("the pool's own §IV.B checks cost single-digit-x; the debug heap's");
    println!("full sweeps cost orders of magnitude — and the pool lets you choose.");

    write_markdown("ablate_guards", &[], &[tab.clone()]).unwrap();
    write_csv("ablate_guards", &[tab]).unwrap();
    println!("wrote bench_out/ablate_guards.md (+csv)");
}
