//! §Traversal: what the traversal-powered maintenance paths cost — KV
//! compaction (pack the live block grid into a compact prefix, return
//! whole regions) and engine snapshot/restore (serialize the full
//! serving state, resume decoding bit-identically).
//!
//! Two arms:
//!
//! * compaction — a fragmented KV grid (every other 2-block sequence
//!   freed → occupancy 0.5, watermark at capacity) compacted in one
//!   call; the JSON summary carries the migration counters CI asserts
//!   (`blocks_migrated`, `regions_returned`, `post_occupancy`).
//! * snapshot   — a mid-decode engine over the mock backend snapshotted
//!   to bytes, restored into a fresh engine, and both run to completion
//!   in lock step; `restore_ok` is 1.0 only if every remaining step and
//!   every output matches.
//!
//! Run: `cargo bench --bench compaction` (arg 1 filters arms by
//! name; `--smoke` shrinks the grid and run count for CI).

use fastpool::bench_harness::{write_json, write_markdown, ReportTable, Suite};
use fastpool::coordinator::{Engine, EngineConfig, MockBackend, SamplingParams};
use fastpool::kvcache::KvCacheManager;
use fastpool::pool::PoolHandle;
use fastpool::util::json::Json;
use fastpool::util::Timer;

const BLOCK_TOKENS: u32 = 16;
const REGION_BLOCKS: u32 = 64;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Build a fragmented manager: `usable` blocks, filled with 2-block
/// sequences, every other sequence freed. Occupancy lands at 0.5 with
/// the watermark pinned at capacity — the shape maintenance sees after
/// a burst of completions.
fn fragmented(usable: u32) -> KvCacheManager {
    let mut kv = KvCacheManager::new(usable + 1, BLOCK_TOKENS, 8);
    let seqs = usable / 2;
    for id in 0..seqs as u64 {
        kv.create_seq(id, 2 * BLOCK_TOKENS).expect("grid sized for exactly this");
    }
    for id in (0..seqs as u64).step_by(2) {
        kv.free_seq(id).unwrap();
    }
    kv
}

fn main() {
    let suite = Suite::new("compaction");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let usable: u32 = if smoke { 256 } else { 2048 };
    let runs: usize = if smoke { 3 } else { 7 };

    let mut tab = ReportTable::new(
        "§Traversal: compaction + snapshot/restore cost",
        "operation",
        vec![
            "kv compact (full grid)".into(),
            "engine snapshot".into(),
            "engine restore".into(),
        ],
        vec!["ns/op".into(), "per block/byte".into()],
        format!("median of {runs} runs; grid {usable} blocks"),
    );
    let mut summary: Vec<(&str, Json)> = vec![
        ("grid_blocks", Json::Num(usable as f64)),
        ("region_blocks", Json::Num(REGION_BLOCKS as f64)),
        ("runs", Json::Num(runs as f64)),
    ];

    // ---- arm 1: compaction -------------------------------------------
    if suite.enabled("compact") {
        let mut ns_runs = Vec::with_capacity(runs);
        let mut last = None;
        for _ in 0..runs {
            let mut kv = fragmented(usable);
            let t = Timer::start();
            let report = kv.compact(REGION_BLOCKS);
            ns_runs.push(t.elapsed_ns() as f64);
            // The compacted grid must re-admit into the freed tail.
            kv.create_seq(u64::from(usable), BLOCK_TOKENS).unwrap();
            last = Some(report);
        }
        let report = last.unwrap();
        let ns = median(ns_runs);
        println!(
            "compact: {ns:>10.0} ns  ({:.1} ns/block)  migrated {} blocks, \
             returned {} regions, occupancy {:.2} -> {:.2}",
            ns / usable as f64,
            report.blocks_migrated,
            report.regions_returned,
            report.pre_occupancy,
            report.post_occupancy,
        );
        tab.set(0, 0, ns);
        tab.set(0, 1, ns / usable as f64);
        summary.push(("compact_ns", Json::Num(ns)));
        summary.push(("blocks_migrated", Json::Num(report.blocks_migrated as f64)));
        summary.push(("regions_returned", Json::Num(report.regions_returned as f64)));
        summary.push(("pre_occupancy", Json::Num(report.pre_occupancy)));
        summary.push(("post_occupancy", Json::Num(report.post_occupancy)));
    }

    // ---- arm 2: snapshot/restore -------------------------------------
    if suite.enabled("snapshot") {
        let mut snap_ns = Vec::with_capacity(runs);
        let mut restore_ns = Vec::with_capacity(runs);
        let mut snapshot_bytes = 0usize;
        let mut restore_ok = true;
        for _ in 0..runs {
            let mut a = Engine::new(MockBackend::new(), EngineConfig::default());
            let prompts: Vec<Vec<i32>> =
                (0..6).map(|i| vec![i + 1, (i + 2) * 3, (i * 7) % 250]).collect();
            for p in &prompts {
                a.submit(p.clone(), SamplingParams::greedy(12)).unwrap();
            }
            for _ in 0..5 {
                a.step().unwrap();
            }

            let t = Timer::start();
            let bytes = a.snapshot();
            snap_ns.push(t.elapsed_ns() as f64);
            snapshot_bytes = bytes.len();

            let t = Timer::start();
            let mut b =
                Engine::restore(MockBackend::new(), PoolHandle::builder().build(), &bytes)
                    .expect("own snapshot must restore");
            restore_ns.push(t.elapsed_ns() as f64);

            // Lock-step to completion: the restored engine must decode
            // bit-identically from where the original stood.
            while a.has_work() || b.has_work() {
                let sa = a.step().unwrap();
                let sb = b.step().unwrap();
                restore_ok &= sa == sb;
            }
            let dump = |v: Vec<fastpool::coordinator::RequestOutput>| {
                let mut d: Vec<String> = v.iter().map(|o| format!("{o:?}")).collect();
                d.sort();
                d
            };
            restore_ok &= dump(a.take_finished()) == dump(b.take_finished());
        }
        let s_ns = median(snap_ns);
        let r_ns = median(restore_ns);
        println!(
            "snapshot: {s_ns:>9.0} ns  ({:.2} ns/byte, {snapshot_bytes} bytes)",
            s_ns / snapshot_bytes as f64
        );
        println!(
            "restore:  {r_ns:>9.0} ns  ({:.2} ns/byte)  lock-step decode identical: {restore_ok}",
            r_ns / snapshot_bytes as f64
        );
        tab.set(1, 0, s_ns);
        tab.set(1, 1, s_ns / snapshot_bytes as f64);
        tab.set(2, 0, r_ns);
        tab.set(2, 1, r_ns / snapshot_bytes as f64);
        summary.push(("snapshot_ns", Json::Num(s_ns)));
        summary.push(("restore_ns", Json::Num(r_ns)));
        summary.push(("snapshot_bytes", Json::Num(snapshot_bytes as f64)));
        summary.push(("restore_ok", Json::Num(if restore_ok { 1.0 } else { 0.0 })));
    }

    let tables = [tab];
    write_markdown("compaction", &[], &tables).unwrap();
    write_json("compaction", &tables, &summary).unwrap();
    println!("wrote bench_out/compaction.json (+md)");
}
