//! **Ablation A1** — the "no loops" contribution in isolation: pool
//! creation cost vs block count, lazy (paper) against the eager-init
//! baseline [6][7] and the pointer free-list pool [14].
//!
//! Expectation: lazy is O(1) — flat as n grows; both eager variants are
//! O(n). Also measures §VII resizing (grow is O(1)) vs re-creating.
//!
//! Run: `cargo bench --bench ablate_create`

use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::pool::{EagerPool, FixedPool, PtrFreeListPool, ResizablePool};
use fastpool::util::black_box;

const NS: &[u32] = &[1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22];
const BLOCK: usize = 64;

fn main() {
    let mut suite = Suite::new("create");
    suite.bencher = fastpool::bench_harness::Bencher::new(
        fastpool::bench_harness::runner::BenchConfig {
            warmup_ns: 5_000_000,
            sample_target_ns: 10_000_000,
            samples: 10,
            max_total_iters: u64::MAX,
        },
    );

    let rows: Vec<String> = NS.iter().map(|n| n.to_string()).collect();
    let cols = vec![
        "lazy (paper)".to_string(),
        "eager-index".to_string(),
        "eager-ptrlist".to_string(),
    ];
    let mut tab = ReportTable::new(
        "A1: pool creation cost vs blocks (64B blocks)",
        "blocks",
        rows,
        cols,
        "µs per create+destroy (median)",
    );

    for (ri, &n) in NS.iter().enumerate() {
        let r_lazy = suite.bencher.bench(format!("create/lazy/n={n}"), || {
            black_box(FixedPool::with_blocks(BLOCK, n));
        });
        println!("{}", r_lazy.one_line());
        tab.set(ri, 0, r_lazy.summary.median / 1e3);

        // Eager variants get too slow for huge n; skip the top sizes to
        // keep the bench bounded (the trend is unambiguous by then).
        if n <= 1 << 20 {
            let r_eager = suite.bencher.bench(format!("create/eager/n={n}"), || {
                black_box(EagerPool::with_blocks(BLOCK, n));
            });
            println!("{}", r_eager.one_line());
            tab.set(ri, 1, r_eager.summary.median / 1e3);

            let r_ptr = suite.bencher.bench(format!("create/ptrlist/n={n}"), || {
                black_box(PtrFreeListPool::with_blocks(BLOCK, n));
            });
            println!("{}", r_ptr.one_line());
            tab.set(ri, 2, r_ptr.summary.median / 1e3);
        }
    }

    // §VII resizing: grow in place vs destroy+recreate at double size.
    let mut tab2 = ReportTable::new(
        "A6-lite: grow-in-place (§VII) vs recreate (128k → 256k blocks)",
        "strategy",
        vec!["grow (member update)".into(), "destroy + recreate".into()],
        vec!["cost".into()],
        "µs (median)",
    );
    {
        let n = 1 << 17;
        let r_grow = suite.bencher.bench("resize/grow", || {
            let mut p = ResizablePool::new(BLOCK, n, 2 * n);
            black_box(p.allocate());
            p.grow(2 * n);
            black_box(p.num_free());
        });
        println!("{}", r_grow.one_line());
        let r_recreate = suite.bencher.bench("resize/recreate", || {
            let p = FixedPool::with_blocks(BLOCK, n);
            drop(p);
            let p2 = FixedPool::with_blocks(BLOCK, 2 * n);
            black_box(p2.num_free());
        });
        println!("{}", r_recreate.one_line());
        tab2.set(0, 0, r_grow.summary.median / 1e3);
        tab2.set(1, 0, r_recreate.summary.median / 1e3);
    }

    println!("\n== A1 summary ==");
    println!("lazy creation stays flat (O(1)); eager variants grow linearly (O(n)).");
    let tables = [tab, tab2];
    write_markdown("ablate_create", &[], &tables).unwrap();
    write_csv("ablate_create", &tables).unwrap();
    println!("wrote bench_out/ablate_create.md (+csv)");
}
