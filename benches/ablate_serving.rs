//! **Ablation A4** — pool-backed vs malloc-backed serving path.
//!
//! Both arms run the *identical* continuous-batching engine over the
//! deterministic MockBackend; the only difference is the allocation
//! handle: `PoolHandle::builder().build()` (per-step buffers, request
//! storage and KV block tables on a `ShardedMultiPool`) vs
//! `PoolHandle::system()` (same code paths, system allocator). The gap
//! is therefore exactly the allocator's share of the serving loop — the
//! paper's claim, measured end-to-end instead of in a micro-loop.
//!
//! **Ablation A4b** (same binary, `-- admission [--smoke]`) A/Bs the
//! occupancy-driven admission controller under open-loop overload:
//! with it off the legacy path admits until the pool exhausts and pays
//! preemptions; with it on, submit-side shedding plus worst-case
//! reservations keep `pool_exhaustion_events` at exactly zero — the
//! invariant CI gates on.
//!
//! Writes `bench_out/ablate_serving.{md,csv,json}`; the JSON summary
//! carries the pooled arm's hit-rate, batched-steal counters, and the
//! A4b admission columns.
//!
//! Run: `cargo bench --bench ablate_serving`

use fastpool::bench_harness::{write_csv, write_json, write_markdown, ReportTable, Suite};
use fastpool::coordinator::{AdmissionConfig, Engine, EngineConfig, MockBackend, SamplingParams};
use fastpool::pool::PoolHandle;
use fastpool::util::json::{self, Json};
use fastpool::util::{Rng, Timer};

const REQUESTS: usize = 384;

/// A4b arm: open-loop overload with occupancy-driven admission on/off.
struct AdmissionArm {
    exhaustion: u64,
    rejected: u64,
    preemptions: u64,
    p50_queue: u64,
    p99_queue: u64,
    completed: usize,
}

/// Drive an overloaded engine (offered concurrency far above both the
/// 8 batch lanes and the 32-data-block KV pool) for `steps`, then
/// drain. With admission off the legacy path admits while blocks fit
/// and pays exhaustion-preemptions; with it on, submit-side shedding
/// plus worst-case reservations keep `pool_exhaustion_events` at zero.
fn run_admission_arm(on: bool, steps: u64, seed: u64) -> AdmissionArm {
    let mut e = Engine::with_pool(
        MockBackend::with_blocks(33, 16, 8),
        EngineConfig {
            max_batch: 8,
            queue_limit: 64,
            admission_ctl: if on { Some(AdmissionConfig::default()) } else { None },
            ..Default::default()
        },
        PoolHandle::builder().build(),
    );
    let mut rng = Rng::new(seed);
    let mut rejected = 0u64;
    for _ in 0..steps {
        for _ in 0..rng.gen_poisson(0.9) {
            let plen = 1 + rng.gen_usize(0, 23);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
            let max_tokens = 16 + rng.gen_range(48) as u32;
            if e.submit(prompt, SamplingParams::greedy(max_tokens)).is_err() {
                rejected += 1;
            }
        }
        e.step().unwrap();
    }
    let outs = e.run_to_completion(10_000_000).unwrap();
    let mut queue: Vec<u64> = outs.iter().map(|o| o.queue_steps).collect();
    queue.sort_unstable();
    let pct = |p: usize| if queue.is_empty() { 0 } else { queue[(queue.len() - 1) * p / 100] };
    AdmissionArm {
        exhaustion: e.metrics.counter("pool_exhaustion_events").get(),
        rejected,
        preemptions: e.metrics.counter("preemptions").get(),
        p50_queue: pct(50),
        p99_queue: pct(99),
        completed: outs.len(),
    }
}

/// One serving run; returns (tokens/s, engine steps, pool hit rate).
fn run_arm(pool: PoolHandle, max_batch: usize, seed: u64) -> (f64, u64, f64) {
    let be = MockBackend::with_blocks(256, 16, 8);
    let mut e = Engine::with_pool(
        be,
        EngineConfig { max_batch, queue_limit: 4096, ..Default::default() },
        pool,
    );
    let mut rng = Rng::new(seed);
    for _ in 0..REQUESTS {
        let plen = 1 + rng.gen_usize(0, 30);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
        e.submit(prompt, SamplingParams::greedy(16 + rng.gen_range(48) as u32))
            .unwrap();
    }
    let t = Timer::start();
    let outs = e.run_to_completion(10_000_000).unwrap();
    let secs = t.elapsed_secs();
    let tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    let hit_rate = e.pool().multi().map_or(0.0, |mp| mp.pool_hit_rate());
    (tokens as f64 / secs, e.steps(), hit_rate)
}

fn median3(f: &dyn Fn() -> (f64, u64, f64)) -> (f64, u64, f64) {
    let mut runs: Vec<(f64, u64, f64)> = (0..3).map(|_| f()).collect();
    runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    runs[1]
}

fn main() {
    let suite = Suite::new("ablate_serving");
    let mut tab = ReportTable::new(
        "A4: serving throughput — cached pool vs bare-sharded pool vs malloc",
        "max_batch",
        vec!["1".into(), "2".into(), "4".into()],
        vec![
            "pool tok/s".into(),
            "uncached tok/s".into(),
            "malloc tok/s".into(),
            "speedup".into(),
            "pool hit %".into(),
        ],
        format!("{REQUESTS} requests, mock model, median of 3"),
    );

    let mut last_hit_rate = 0.0;
    if suite.enabled("throughput") {
        for (ri, mb) in [1usize, 2, 4].into_iter().enumerate() {
            let (pool_tps, steps_p, hit) =
                median3(&|| run_arm(PoolHandle::builder().build(), mb, 7));
            let (bare_tps, steps_b, _) =
                median3(&|| run_arm(PoolHandle::builder().magazines(false).build(), mb, 7));
            let (sys_tps, steps_s, _) = median3(&|| run_arm(PoolHandle::system(), mb, 7));
            assert_eq!(
                steps_p, steps_s,
                "arms must schedule identically — same engine, same workload"
            );
            assert_eq!(steps_p, steps_b, "cached and uncached arms must agree too");
            last_hit_rate = hit;
            println!(
                "max_batch={mb}: pool {pool_tps:>10.0} tok/s | uncached {bare_tps:>10.0} | malloc {sys_tps:>10.0} tok/s | x{:.3} | hit {:.1}%",
                pool_tps / sys_tps,
                hit * 100.0
            );
            tab.set(ri, 0, pool_tps);
            tab.set(ri, 1, bare_tps);
            tab.set(ri, 2, sys_tps);
            tab.set(ri, 3, pool_tps / sys_tps);
            tab.set(ri, 4, hit * 100.0);
        }
    }

    // Batched-steal counters from a contended pooled run (many worker
    // threads submitting through one shared multi-pool).
    let mut steal_summary: Vec<(&str, Json)> = Vec::new();
    if suite.enabled("steals") {
        let handle = PoolHandle::builder().build();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let handle = handle.clone();
                s.spawn(move || {
                    let _ = run_arm(handle, 4, 11 + t);
                });
            }
        });
        let mp = handle.multi().unwrap();
        let (mut steals, mut scans, mut stash_hits) = (0u64, 0u64, 0u64);
        for ci in 0..mp.num_classes() {
            let st = mp.class_shard_stats(ci);
            steals += st.total_steals();
            scans += st.total_steal_scans();
            stash_hits += st.total_stash_hits();
        }
        let avg_batch = if scans == 0 { 0.0 } else { steals as f64 / scans as f64 };
        println!(
            "contended pool: {steals} blocks stolen over {scans} scans (avg batch {avg_batch:.2}), {stash_hits} stash hits"
        );
        steal_summary.push(("stolen_blocks", Json::Num(steals as f64)));
        steal_summary.push(("steal_scans", Json::Num(scans as f64)));
        steal_summary.push(("stash_hits", Json::Num(stash_hits as f64)));
        steal_summary.push(("avg_steal_batch", Json::Num(avg_batch)));
        let ms = mp.magazine_stats();
        println!(
            "contended pool magazines: {} hits / {} refills / {} flushes ({:.0} hits per refill)",
            ms.hits,
            ms.refills,
            ms.flushes,
            ms.hits_per_refill()
        );
        steal_summary.push(("magazine_hits", Json::Num(ms.hits as f64)));
        steal_summary.push(("magazine_refills", Json::Num(ms.refills as f64)));
        steal_summary.push(("magazine_flushes", Json::Num(ms.flushes as f64)));
        steal_summary.push(("magazine_hits_per_refill", Json::Num(ms.hits_per_refill())));
    }

    // A4b: occupancy-driven admission control on/off under overload.
    // Smoke mode (`-- admission --smoke`) shortens the drive for CI,
    // which gates on `exhaustion_admission_on == 0 &&
    // exhaustion_admission_off >= 1` in the JSON summary.
    let mut adm_tab = ReportTable::new(
        "A4b: admission control on/off under open-loop overload",
        "admission",
        vec!["on".into(), "off".into()],
        vec![
            "exhaustion".into(),
            "rejected".into(),
            "preemptions".into(),
            "p50 queue".into(),
            "p99 queue".into(),
            "completed".into(),
        ],
        "Poisson 0.9 req/step, 8 lanes, 32 KV blocks".to_string(),
    );
    let mut admission_summary: Vec<(&str, Json)> = Vec::new();
    if suite.enabled("admission") {
        let smoke = std::env::args().any(|a| a == "--smoke");
        let steps = if smoke { 2_000 } else { 12_000 };
        for (ri, on) in [true, false].into_iter().enumerate() {
            let arm = run_admission_arm(on, steps, 23);
            println!(
                "admission {}: exhaustion {} | rejected {} | preemptions {} | queue p50/p99 {}/{} | completed {}",
                if on { "on " } else { "off" },
                arm.exhaustion,
                arm.rejected,
                arm.preemptions,
                arm.p50_queue,
                arm.p99_queue,
                arm.completed
            );
            adm_tab.set(ri, 0, arm.exhaustion as f64);
            adm_tab.set(ri, 1, arm.rejected as f64);
            adm_tab.set(ri, 2, arm.preemptions as f64);
            adm_tab.set(ri, 3, arm.p50_queue as f64);
            adm_tab.set(ri, 4, arm.p99_queue as f64);
            adm_tab.set(ri, 5, arm.completed as f64);
            admission_summary.push((
                if on { "exhaustion_admission_on" } else { "exhaustion_admission_off" },
                Json::Num(arm.exhaustion as f64),
            ));
            admission_summary.push((
                if on { "rejected_admission_on" } else { "rejected_admission_off" },
                Json::Num(arm.rejected as f64),
            ));
            admission_summary.push((
                if on { "preemptions_admission_on" } else { "preemptions_admission_off" },
                Json::Num(arm.preemptions as f64),
            ));
            admission_summary.push((
                if on { "p99_queue_admission_on" } else { "p99_queue_admission_off" },
                Json::Num(arm.p99_queue as f64),
            ));
        }
    }

    let mut summary = vec![
        ("requests", Json::Num(REQUESTS as f64)),
        ("pool_hit_rate", Json::Num(last_hit_rate)),
        ("mode", json::s("mock-engine A/B, allocation handle only")),
    ];
    summary.extend(steal_summary);
    summary.extend(admission_summary);

    let tables = [tab, adm_tab];
    write_markdown("ablate_serving", &[], &tables).unwrap();
    write_csv("ablate_serving", &tables).unwrap();
    write_json("ablate_serving", &tables, &summary).unwrap();
    println!("\nwrote bench_out/ablate_serving.json (+md, csv)");
}
