//! **Figure 3 reproduction** — "Release build with full optimization
//! running within the debugger; system malloc only" (E1 in DESIGN.md).
//!
//! The debugger-attached Windows CRT heap is simulated by
//! `DebugHeapAllocator` (guard bands + fills + allocation registry +
//! full-heap verification sweeps — exactly the cost drivers of the debug
//! CRT; see DESIGN.md substitution table). Two levels are reported:
//!
//! * `malloc-debug`    — guards/fills/registry only (≈ debug build)
//! * `malloc-debugger` — plus a heap sweep on every alloc AND free
//!                       (≈ debugger attached), the paper's ~100–1000×.
//!
//! Counts are capped lower than Figure 4: the sweep makes each cycle
//! O(n²), which is precisely the point the figure makes.
//!
//! Run: `cargo bench --bench fig3_debug_malloc`

use fastpool::alloc::{
    AllocHandle, BenchAllocator, DebugHeapAllocator, DebugLevel, PoolAllocator,
    SystemAllocator,
};
use fastpool::bench_harness::{write_csv, write_markdown, BenchResult, ReportTable, Suite};
use fastpool::util::black_box;

const SIZES: &[u32] = &[16, 64, 256, 1024, 4096];
const COUNTS: &[u32] = &[256, 512, 1_024, 2_048, 4_096];

fn run_cycle(a: &mut dyn BenchAllocator, n: u32, size: u32, held: &mut Vec<AllocHandle>) {
    for _ in 0..n {
        match a.alloc(size as usize) {
            Some(h) => held.push(h),
            None => break,
        }
    }
    for h in held.drain(..) {
        a.free(h);
    }
}

fn main() {
    let suite = Suite::new("fig3");
    let bencher = fastpool::bench_harness::Bencher::new(
        fastpool::bench_harness::runner::BenchConfig {
            warmup_ns: 5_000_000,
            sample_target_ns: 40_000_000,
            samples: 5,
            max_total_iters: u64::MAX,
        },
    );

    let col_labels: Vec<String> = SIZES.iter().map(|s| format!("{s}B")).collect();
    let row_labels: Vec<String> = COUNTS.iter().map(|c| c.to_string()).collect();
    let mut tab_dbg = ReportTable::new(
        "Figure 3: malloc 'within the debugger' (simulated debug heap, full sweeps)",
        "allocations",
        row_labels.clone(),
        col_labels.clone(),
        "ms per cycle (median)",
    );
    let mut tab_light = ReportTable::new(
        "Debug build (guards+fills+registry, no sweeps)",
        "allocations",
        row_labels.clone(),
        col_labels.clone(),
        "ms per cycle (median)",
    );
    let mut tab_ratio = ReportTable::new(
        "Slowdown: debugger-malloc / release-malloc (paper: 'up to 100x'…'1000x')",
        "allocations",
        row_labels,
        col_labels,
        "x slower than release malloc",
    );
    let mut results: Vec<BenchResult> = Vec::new();

    for (ci, &size) in SIZES.iter().enumerate() {
        for (ri, &n) in COUNTS.iter().enumerate() {
            let name = format!("debugger/n={n}/size={size}");
            if !suite.enabled(&name) {
                continue;
            }
            let mut held = Vec::with_capacity(n as usize);

            // Release malloc baseline for the ratio.
            let mut rel = SystemAllocator::new();
            let rr = bencher.bench_with_elements(
                &format!("malloc-release/n={n}/size={size}"),
                n as u64,
                &mut || {
                    run_cycle(&mut rel, n, size, &mut held);
                    black_box(&mut held);
                },
            );
            println!("{}", rr.one_line());

            let mut light = DebugHeapAllocator::new(DebugLevel::Light);
            let rl = bencher.bench_with_elements(
                &format!("malloc-debug/n={n}/size={size}"),
                n as u64,
                &mut || {
                    run_cycle(&mut light, n, size, &mut held);
                    black_box(&mut held);
                },
            );
            println!("{}", rl.one_line());

            let mut dbg = DebugHeapAllocator::new(DebugLevel::Full);
            let rd = bencher.bench_with_elements(&name, n as u64, &mut || {
                run_cycle(&mut dbg, n, size, &mut held);
                black_box(&mut held);
            });
            println!("{}", rd.one_line());

            tab_light.set(ri, ci, rl.summary.median / 1e6);
            tab_dbg.set(ri, ci, rd.summary.median / 1e6);
            tab_ratio.set(ri, ci, rd.summary.median / rr.summary.median);
            results.push(rr);
            results.push(rl);
            results.push(rd);
        }
    }

    // Pool-vs-debugger headline (the paper's "thousand times faster").
    {
        let n = 2_048u32;
        let size = 64u32;
        let mut held = Vec::with_capacity(n as usize);
        let mut pool = PoolAllocator::new(size as usize, n);
        let rp = bencher.bench_with_elements("pool/n=2048/size=64", n as u64, &mut || {
            run_cycle(&mut pool, n, size, &mut held);
            black_box(&mut held);
        });
        let mut dbg = DebugHeapAllocator::new(DebugLevel::Full);
        let rd = bencher.bench_with_elements(
            "debugger-malloc/n=2048/size=64",
            n as u64,
            &mut || {
                run_cycle(&mut dbg, n, size, &mut held);
                black_box(&mut held);
            },
        );
        println!("\n== Figure 3 headline ==");
        println!(
            "pool vs debugger-malloc at n=2048/64B: {:.0}x faster",
            rd.summary.median / rp.summary.median
        );
        println!("(paper: \"a thousand times faster when running within a debug environment\")");
        results.push(rp);
        results.push(rd);
    }

    let tables = [tab_dbg, tab_light, tab_ratio];
    write_markdown("fig3_debug_malloc", &results, &tables).unwrap();
    write_csv("fig3_debug_malloc", &tables).unwrap();
    println!("\nwrote bench_out/fig3_debug_malloc.md (+csv)");
}
