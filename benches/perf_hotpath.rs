//! §Perf microbench: the pool hot path in isolation, for the optimization
//! loop (EXPERIMENTS.md §Perf). Three access shapes:
//!
//! * pair      — alloc;free (head stays hot: best case)
//! * batch64   — alloc 64; free 64 LIFO (L1-resident working set)
//! * churn1k   — random replace in a 1k live set (cache-realistic)
//!
//! Arms, in hot-path lineage order (see `pool/mod.rs`):
//!
//! * fixed     — the paper's single-thread pool (`FixedPool`, `&mut`)
//! * malloc    — libc baseline
//! * blockalloc— the KV manager's index allocator (pair row only)
//! * atomic    — lock-free Treiber (`AtomicPool`): 2 CAS per pair
//! * sharded   — `ShardedPool`: same 2 CAS, but uncontended/core-local
//! * magazine  — `MagazinePool`: 0 CAS steady state; refills/flushes
//!               amortise shared traffic to ~1 CAS per magazine
//!
//! The headline this bench exists to track: the magazine arm beating the
//! bare sharded arm on the pair shape, with the amortisation visible in
//! the `magazine_*` counters of the JSON summary
//! (`bench_out/perf_hotpath.json`).
//!
//! Run: `cargo bench --bench perf_hotpath` (arg 1 filters shapes by
//! name; `--smoke` shrinks iteration counts for CI).

use core::ptr::NonNull;

use fastpool::bench_harness::{write_json, write_markdown, ReportTable, Suite};
use fastpool::kvcache::BlockAllocator;
use fastpool::pool::{AtomicPool, FixedPool, MagazinePool, ShardedPool, DEFAULT_MAG_DEPTH};
use fastpool::util::json::Json;
use fastpool::util::{black_box, Rng, Timer};

extern crate libc;

const BLOCK: usize = 64;
const POOL_BLOCKS: u32 = 2048;
const SHARDS: usize = 8;
const LIVE: usize = 1024;

/// One allocator under test: tokens are opaque (pointer or index).
trait Arm {
    fn alloc(&mut self) -> u64;
    fn free(&mut self, t: u64);
}

struct FixedArm(FixedPool);
impl Arm for FixedArm {
    fn alloc(&mut self) -> u64 {
        self.0.allocate().expect("fixed pool sized for the shape").as_ptr() as u64
    }
    fn free(&mut self, t: u64) {
        // SAFETY: `t` is a token from this arm's `alloc`, so it is non-null.
        let p = unsafe { NonNull::new_unchecked(t as *mut u8) };
        // SAFETY: the harness frees each token exactly once.
        unsafe { self.0.deallocate(p) }
    }
}

struct MallocArm;
impl Arm for MallocArm {
    fn alloc(&mut self) -> u64 {
        // SAFETY: plain malloc; the token only travels back to `free`.
        unsafe { libc::malloc(BLOCK) as u64 }
    }
    fn free(&mut self, t: u64) {
        // SAFETY: `t` came from `malloc` in `alloc`, freed exactly once.
        unsafe { libc::free(t as *mut libc::c_void) }
    }
}

struct AtomicArm(AtomicPool);
impl Arm for AtomicArm {
    fn alloc(&mut self) -> u64 {
        self.0.allocate().expect("atomic pool sized for the shape").as_ptr() as u64
    }
    fn free(&mut self, t: u64) {
        // SAFETY: `t` is a token from this arm's `alloc`, so it is non-null.
        let p = unsafe { NonNull::new_unchecked(t as *mut u8) };
        // SAFETY: the harness frees each token exactly once.
        unsafe { self.0.deallocate(p) }
    }
}

struct ShardedArm(ShardedPool);
impl Arm for ShardedArm {
    fn alloc(&mut self) -> u64 {
        self.0.allocate().expect("sharded pool sized for the shape").as_ptr() as u64
    }
    fn free(&mut self, t: u64) {
        // SAFETY: `t` is a token from this arm's `alloc`, so it is non-null.
        let p = unsafe { NonNull::new_unchecked(t as *mut u8) };
        // SAFETY: the harness frees each token exactly once.
        unsafe { self.0.deallocate(p) }
    }
}

struct MagazineArm(MagazinePool);
impl Arm for MagazineArm {
    fn alloc(&mut self) -> u64 {
        self.0.allocate().expect("magazine pool sized for the shape").as_ptr() as u64
    }
    fn free(&mut self, t: u64) {
        // SAFETY: `t` is a token from this arm's `alloc`, so it is non-null.
        let p = unsafe { NonNull::new_unchecked(t as *mut u8) };
        // SAFETY: the harness frees each token exactly once.
        unsafe { self.0.deallocate(p) }
    }
}

fn make_arm(name: &str) -> Box<dyn Arm> {
    match name {
        "fixed" => Box::new(FixedArm(FixedPool::with_blocks(BLOCK, POOL_BLOCKS))),
        "malloc" => Box::new(MallocArm),
        "atomic" => Box::new(AtomicArm(AtomicPool::with_blocks(BLOCK, POOL_BLOCKS))),
        "sharded" => {
            Box::new(ShardedArm(ShardedPool::with_shards(BLOCK, POOL_BLOCKS, SHARDS)))
        }
        "magazine" => Box::new(MagazineArm(MagazinePool::with_shards(
            BLOCK,
            POOL_BLOCKS,
            SHARDS,
            DEFAULT_MAG_DEPTH,
        ))),
        _ => unreachable!("unknown arm {name}"),
    }
}

fn pair_shape(a: &mut dyn Arm, n: usize) -> f64 {
    let t = Timer::start();
    for _ in 0..n {
        let x = a.alloc();
        a.free(black_box(x));
    }
    t.elapsed_ns() as f64 / n as f64
}

fn batch64_shape(a: &mut dyn Arm, n: usize) -> f64 {
    let mut held = Vec::with_capacity(64);
    let t = Timer::start();
    for _ in 0..n / 64 {
        for _ in 0..64 {
            held.push(a.alloc());
        }
        while let Some(x) = held.pop() {
            a.free(black_box(x));
        }
    }
    t.elapsed_ns() as f64 / n as f64
}

fn churn1k_shape(a: &mut dyn Arm, n: usize) -> f64 {
    let mut rng = Rng::new(1);
    let mut live: Vec<u64> = (0..LIVE).map(|_| a.alloc()).collect();
    let t = Timer::start();
    for _ in 0..n {
        let i = rng.gen_usize(0, live.len());
        a.free(live[i]);
        live[i] = a.alloc();
    }
    let ns = t.elapsed_ns() as f64 / n as f64;
    for x in live {
        a.free(x);
    }
    ns
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

const ARMS: &[&str] = &["fixed", "malloc", "atomic", "sharded", "magazine"];
const SHAPES: &[&str] = &["pair", "batch64", "churn1k"];

fn main() {
    let suite = Suite::new("perf_hotpath");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n: usize = if smoke { 50_000 } else { 1_000_000 };
    let runs: usize = if smoke { 3 } else { 9 };

    let mut tab = ReportTable::new(
        "§Perf: hot-path latency by shape and allocator tier",
        "shape",
        SHAPES.iter().map(|s| s.to_string()).collect(),
        ARMS.iter()
            .map(|a| a.to_string())
            .chain(std::iter::once("blockalloc".to_string()))
            .collect(),
        format!("ns per op (median of {runs} runs of {n} ops)"),
    );

    let mut cell = vec![vec![f64::NAN; ARMS.len()]; SHAPES.len()];
    for (si, shape) in SHAPES.iter().enumerate() {
        for (ai, arm) in ARMS.iter().enumerate() {
            let name = format!("{shape}/{arm}");
            if !suite.enabled(&name) {
                continue;
            }
            let m = median(
                (0..runs)
                    .map(|_| {
                        let mut a = make_arm(arm);
                        match *shape {
                            "pair" => pair_shape(a.as_mut(), n),
                            "batch64" => batch64_shape(a.as_mut(), n),
                            _ => churn1k_shape(a.as_mut(), n),
                        }
                    })
                    .collect(),
            );
            println!("{name:<20} {m:>8.2} ns/op");
            cell[si][ai] = m;
            tab.set(si, ai, m);
        }
    }

    // Pair-only extra: the KV manager's index allocator (the paper's
    // bookkeeping flavour — no pointers, so it sits outside the Arm grid).
    if suite.enabled("pair/blockalloc") {
        let m = median(
            (0..runs)
                .map(|_| {
                    let mut p = BlockAllocator::new(POOL_BLOCKS);
                    let t = Timer::start();
                    for _ in 0..n {
                        let i = p.allocate().unwrap();
                        p.free(black_box(i));
                    }
                    t.elapsed_ns() as f64 / n as f64
                })
                .collect(),
        );
        println!("{:<20} {m:>8.2} ns/op", "pair/blockalloc");
        tab.set(0, ARMS.len(), m);
    }

    // Instrumented magazine pair run: the amortisation proof. The
    // counters — not the timer — are what the acceptance criterion
    // checks: hits/refill ≥ one magazine of ops means the shared-pool
    // CAS traffic is ≤ 1 per magazine.
    let mag = MagazinePool::with_shards(BLOCK, POOL_BLOCKS, SHARDS, DEFAULT_MAG_DEPTH);
    for _ in 0..n {
        let p = mag.allocate().unwrap();
        // SAFETY: `p` came from `allocate` and is freed exactly once.
        unsafe { mag.deallocate(black_box(p)) };
    }
    let ms = mag.magazine_stats();
    println!(
        "\nmagazine pair counters: {} hits / {} refills ({:.0} ops per refill, hit rate {:.4})",
        ms.hits,
        ms.refills,
        ms.hits_per_refill(),
        ms.hit_rate()
    );

    let pair_sharded = cell[0][3];
    let pair_magazine = cell[0][4];
    let mut summary = vec![
        ("ops", Json::Num(n as f64)),
        ("runs", Json::Num(runs as f64)),
        ("block_size", Json::Num(BLOCK as f64)),
        ("shards", Json::Num(SHARDS as f64)),
        ("magazine_pair_hits", Json::Num(ms.hits as f64)),
        ("magazine_pair_refills", Json::Num(ms.refills as f64)),
        ("magazine_hits_per_refill", Json::Num(ms.hits_per_refill())),
        ("magazine_hit_rate", Json::Num(ms.hit_rate())),
    ];
    if pair_sharded.is_finite() && pair_magazine.is_finite() {
        let speedup = pair_sharded / pair_magazine;
        println!(
            "pair: magazine {pair_magazine:.2} ns vs sharded {pair_sharded:.2} ns ({speedup:.2}x)"
        );
        summary.push(("magazine_vs_sharded_pair_speedup", Json::Num(speedup)));
        summary.push(("sharded_pair_ns", Json::Num(pair_sharded)));
        summary.push(("magazine_pair_ns", Json::Num(pair_magazine)));
    }

    let tables = [tab];
    write_markdown("perf_hotpath", &[], &tables).unwrap();
    write_json("perf_hotpath", &tables, &summary).unwrap();
    println!("wrote bench_out/perf_hotpath.json (+md)");
}
