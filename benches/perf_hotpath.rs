//! §Perf microbench: the pool hot path in isolation, for the optimization
//! loop (EXPERIMENTS.md §Perf). Three access shapes:
//!
//! * pair      — alloc;free (head stays hot: best case)
//! * batch64   — alloc 64; free 64 LIFO (L1-resident working set)
//! * churn1k   — random replace in a 1k live set (cache-realistic)
//!
//! Compares the paper pool against malloc and the index allocator used by
//! the KV manager.
//!
//! Run: `cargo bench --bench perf_hotpath`

use fastpool::kvcache::BlockAllocator;
use fastpool::pool::FixedPool;
use fastpool::util::{black_box, Rng, Timer};

extern crate libc;

const BLOCK: usize = 64;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut() -> f64>(name: &str, mut f: F) -> f64 {
    let m = median((0..9).map(|_| f()).collect());
    println!("{name:<28} {m:>8.2} ns/op");
    m
}

fn main() {
    const N: usize = 1_000_000;

    println!("-- pair (alloc;free, hot head) --");
    let pool_pair = bench("pool pair", || {
        let mut p = FixedPool::with_blocks(BLOCK, 1024);
        let t = Timer::start();
        for _ in 0..N {
            let a = p.allocate().unwrap();
            unsafe { p.deallocate(black_box(a)) };
        }
        t.elapsed_ns() as f64 / N as f64
    });
    let malloc_pair = bench("malloc pair", || {
        let t = Timer::start();
        for _ in 0..N {
            let a = unsafe { libc::malloc(BLOCK) };
            unsafe { libc::free(black_box(a)) };
        }
        t.elapsed_ns() as f64 / N as f64
    });
    bench("blockalloc pair (index)", || {
        let mut p = BlockAllocator::new(1024);
        let t = Timer::start();
        for _ in 0..N {
            let a = p.allocate().unwrap();
            p.free(black_box(a));
        }
        t.elapsed_ns() as f64 / N as f64
    });

    println!("-- batch64 (alloc 64, free 64 LIFO) --");
    bench("pool batch64", || {
        let mut p = FixedPool::with_blocks(BLOCK, 128);
        let mut held = Vec::with_capacity(64);
        let t = Timer::start();
        for _ in 0..N / 64 {
            for _ in 0..64 {
                held.push(p.allocate().unwrap());
            }
            while let Some(a) = held.pop() {
                unsafe { p.deallocate(a) };
            }
        }
        t.elapsed_ns() as f64 / N as f64
    });
    bench("malloc batch64", || {
        let mut held: Vec<*mut libc::c_void> = Vec::with_capacity(64);
        let t = Timer::start();
        for _ in 0..N / 64 {
            for _ in 0..64 {
                held.push(unsafe { libc::malloc(BLOCK) });
            }
            while let Some(a) = held.pop() {
                unsafe { libc::free(a) };
            }
        }
        t.elapsed_ns() as f64 / N as f64
    });

    println!("-- churn1k (random replace in 1k live set) --");
    let pool_churn = bench("pool churn1k", || {
        let mut p = FixedPool::with_blocks(BLOCK, 2048);
        let mut rng = Rng::new(1);
        let mut live: Vec<_> = (0..1024).map(|_| p.allocate().unwrap()).collect();
        let t = Timer::start();
        for _ in 0..N {
            let i = rng.gen_usize(0, live.len());
            unsafe { p.deallocate(live[i]) };
            live[i] = p.allocate().unwrap();
        }
        let ns = t.elapsed_ns() as f64 / N as f64;
        for a in live {
            unsafe { p.deallocate(a) };
        }
        ns
    });
    let malloc_churn = bench("malloc churn1k", || {
        let mut rng = Rng::new(1);
        let mut live: Vec<*mut libc::c_void> =
            (0..1024).map(|_| unsafe { libc::malloc(BLOCK) }).collect();
        let t = Timer::start();
        for _ in 0..N {
            let i = rng.gen_usize(0, live.len());
            unsafe { libc::free(live[i]) };
            live[i] = unsafe { libc::malloc(BLOCK) };
        }
        let ns = t.elapsed_ns() as f64 / N as f64;
        for a in live {
            unsafe { libc::free(a) };
        }
        ns
    });

    println!("\npair speedup vs malloc:  {:.2}x", malloc_pair / pool_pair);
    println!("churn speedup vs malloc: {:.2}x", malloc_churn / pool_churn);
}
