//! **Ablation A7** — §VI's fragmentation claim, measured: long-running
//! churn on a general first-fit allocator vs the pool. Tracks external
//! fragmentation and first-fit search length over time; the pool's
//! invariants (zero frag, O(1) "search") are the paper's selling point.
//!
//! Run: `cargo bench --bench ablate_frag`

use fastpool::alloc::{
    pool_frag_metrics, BenchAllocator, FirstFitAllocator, PoolAllocator,
};
use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::util::Rng;

const EPOCHS: usize = 10;
const OPS_PER_EPOCH: usize = 20_000;
const LIVE: usize = 700;
const ARENA: usize = 1 << 21; // 2 MiB

fn main() {
    let suite = Suite::new("frag");
    if !suite.enabled("frag") {
        return;
    }
    let mut ff = FirstFitAllocator::new(ARENA);
    // Pool for the dominant size class (128B covers the small mix).
    let mut pool = PoolAllocator::new(256, (LIVE * 2) as u32);

    let mut tab = ReportTable::new(
        "A7: fragmentation + search cost over churn epochs (mixed 16..1024B)",
        "epoch",
        (1..=EPOCHS).map(|e| e.to_string()).collect(),
        vec![
            "firstfit ext-frag %".into(),
            "firstfit mean search".into(),
            "firstfit ns/op".into(),
            "pool ext-frag %".into(),
            "pool ns/op".into(),
        ],
        "measured at epoch end",
    );

    let mut rng = Rng::new(17);
    let mut ff_live: Vec<fastpool::alloc::AllocHandle> = Vec::new();
    let mut pool_live: Vec<fastpool::alloc::AllocHandle> = Vec::new();

    for epoch in 0..EPOCHS {
        // First-fit with a hostile-but-realistic mixed-size churn.
        let t = fastpool::util::Timer::start();
        let search_before = ff.total_search_steps;
        let allocs_before = ff.total_allocs;
        for _ in 0..OPS_PER_EPOCH {
            if ff_live.is_empty() || (ff_live.len() < LIVE && rng.gen_bool(0.53)) {
                let size = 16 << rng.gen_usize(0, 7); // 16..1024
                if let Some(h) = ff.alloc(size) {
                    ff_live.push(h);
                }
            } else {
                let i = rng.gen_usize(0, ff_live.len());
                ff.free(ff_live.swap_remove(i));
            }
        }
        let ff_ns = t.elapsed_ns() as f64 / OPS_PER_EPOCH as f64;
        let m = ff.frag_metrics();
        let searches = (ff.total_search_steps - search_before) as f64
            / (ff.total_allocs - allocs_before).max(1) as f64;

        // Pool under the same op sequence shape (fixed 256B slots — the
        // pool's deal: one class per pool).
        let t = fastpool::util::Timer::start();
        let mut rng2 = Rng::new(17 ^ (epoch as u64 + 1));
        for _ in 0..OPS_PER_EPOCH {
            if pool_live.is_empty() || (pool_live.len() < LIVE && rng2.gen_bool(0.53)) {
                if let Some(h) = pool.alloc(256) {
                    pool_live.push(h);
                }
            } else {
                let i = rng2.gen_usize(0, pool_live.len());
                pool.free(pool_live.swap_remove(i));
            }
        }
        let pool_ns = t.elapsed_ns() as f64 / OPS_PER_EPOCH as f64;
        let pm = pool_frag_metrics(pool.pool().num_free(), pool.pool().block_size());

        println!(
            "epoch {:>2}: firstfit frag {:>5.1}% search {:>6.1} {:>7.1} ns/op | pool frag {:>4.1}% {:>6.1} ns/op",
            epoch + 1,
            m.external_frag() * 100.0,
            searches,
            ff_ns,
            pm.external_frag() * 100.0,
            pool_ns
        );
        tab.set(epoch, 0, m.external_frag() * 100.0);
        tab.set(epoch, 1, searches);
        tab.set(epoch, 2, ff_ns);
        tab.set(epoch, 3, pm.external_frag() * 100.0);
        tab.set(epoch, 4, pool_ns);
    }

    // Cleanup.
    for h in ff_live {
        ff.free(h);
    }
    for h in pool_live {
        pool.free(h);
    }

    println!("\n== A7 summary ==");
    println!("first-fit fragmentation and search length drift upward with churn;");
    println!("the pool stays at 0% fragmentation and constant-time ops (§VI).");

    write_markdown("ablate_frag", &[], &[tab.clone()]).unwrap();
    write_csv("ablate_frag", &[tab]).unwrap();
    println!("wrote bench_out/ablate_frag.md (+csv)");
}
