//! **Experiment A8** — the framework headline: end-to-end serving
//! throughput with the paper's pool as KV-block manager.
//!
//! Part 1 (always runs): scheduler-only throughput with the deterministic
//! MockBackend — isolates the L3 coordinator + pool path. Compares the
//! paper's lazy BlockAllocator against an eager-init variant and measures
//! pool-op share of the step loop.
//!
//! Part 2 (runs when artifacts/ exists): the real PJRT model, batched
//! decode tokens/s at batch 1/2/4, plus model-vs-engine time split.
//!
//! Run: `cargo bench --bench serving_e2e`

use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::coordinator::{
    Engine, EngineConfig, MockBackend, SamplingParams, XlaBackend,
};
use fastpool::kvcache::BlockAllocator;
use fastpool::runtime::Runtime;
use fastpool::util::{Rng, Timer};

fn mock_engine_run(n_requests: usize, max_batch: usize) -> (f64, u64) {
    let be = MockBackend::with_blocks(256, 16, 8);
    let mut e = Engine::new(be, EngineConfig { max_batch, queue_limit: 4096, ..Default::default() });
    let mut rng = Rng::new(7);
    for _ in 0..n_requests {
        let plen = 1 + rng.gen_usize(0, 30);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
        e.submit(prompt, SamplingParams::greedy(16 + rng.gen_range(48) as u32))
            .unwrap();
    }
    let t = Timer::start();
    let outs = e.run_to_completion(10_000_000).unwrap();
    let secs = t.elapsed_secs();
    let tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
    (tokens as f64 / secs, e.steps())
}

fn main() {
    let suite = Suite::new("serving");

    // ---- Part 1: coordinator throughput (mock model) --------------------
    let mut tab1 = ReportTable::new(
        "A8.1: scheduler throughput, mock model (pool-managed KV blocks)",
        "max_batch",
        vec!["1".into(), "2".into(), "4".into()],
        vec!["tokens/s".into(), "engine steps".into()],
        "512 requests, median of 3",
    );
    if suite.enabled("scheduler") {
        for (ri, mb) in [1usize, 2, 4].into_iter().enumerate() {
            let mut runs: Vec<(f64, u64)> =
                (0..3).map(|_| mock_engine_run(512, mb)).collect();
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let (tps, steps) = runs[1];
            println!("mock scheduler max_batch={mb}: {tps:>10.0} tok/s in {steps} steps");
            tab1.set(ri, 0, tps);
            tab1.set(ri, 1, steps as f64);
        }
    }

    // ---- Pool-op share of the serving hot path --------------------------
    let mut tab2 = ReportTable::new(
        "A8.2: KV block-pool op cost inside the serving loop",
        "op",
        vec![
            "allocate (lazy, paper)".into(),
            "free".into(),
            "serving-trace replay / op".into(),
        ],
        vec!["ns".into()],
        "median of 7",
    );
    if suite.enabled("poolops") {
        let med = |f: &dyn Fn() -> f64| {
            let mut xs: Vec<f64> = (0..7).map(|_| f()).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[3]
        };
        let alloc_ns = med(&|| {
            let mut a = BlockAllocator::new(4096);
            let t = Timer::start();
            for _ in 0..4096 {
                std::hint::black_box(a.allocate());
            }
            t.elapsed_ns() as f64 / 4096.0
        });
        let free_ns = med(&|| {
            let mut a = BlockAllocator::new(4096);
            let idxs: Vec<u32> = (0..4096).map(|_| a.allocate().unwrap()).collect();
            let t = Timer::start();
            for i in idxs {
                a.free(i);
            }
            t.elapsed_ns() as f64 / 4096.0
        });
        // Replay the serving block trace through the allocator.
        let trace_ns = med(&|| {
            let (trace, _, stats) = fastpool::workload::serving::generate(
                fastpool::workload::serving::ServingConfig::default(),
                3,
            );
            let mut a = BlockAllocator::new(stats.peak_live_blocks + 8);
            let mut live: Vec<Option<u32>> = vec![None; trace.num_allocs() + 1];
            let t = Timer::start();
            for op in &trace.ops {
                match *op {
                    fastpool::workload::Op::Alloc { id, .. } => {
                        live[id as usize] = a.allocate();
                    }
                    fastpool::workload::Op::Free { id } => {
                        if let Some(b) = live[id as usize].take() {
                            a.free(b);
                        }
                    }
                }
            }
            t.elapsed_ns() as f64 / trace.ops.len() as f64
        });
        println!("block-pool: alloc {alloc_ns:.2} ns | free {free_ns:.2} ns | serving trace {trace_ns:.2} ns/op");
        tab2.set(0, 0, alloc_ns);
        tab2.set(1, 0, free_ns);
        tab2.set(2, 0, trace_ns);
    }

    // ---- Part 2: real model (needs artifacts) ----------------------------
    let mut tab3 = ReportTable::new(
        "A8.3: real PJRT model serving (tokens/s by batch)",
        "max_batch",
        vec!["1".into(), "2".into(), "4".into()],
        vec!["tokens/s".into(), "model time %".into()],
        "12 requests x 16 tokens",
    );
    if std::path::Path::new("artifacts/meta.json").exists() && suite.enabled("xla") {
        for (ri, mb) in [1usize, 2, 4].into_iter().enumerate() {
            let rt = Runtime::load("artifacts").unwrap();
            let be = XlaBackend::new(rt).unwrap();
            let mut e = Engine::new(be, EngineConfig { max_batch: mb, ..Default::default() });
            let mut rng = Rng::new(3);
            for _ in 0..12 {
                let plen = 4 + rng.gen_usize(0, 20);
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.gen_range(256) as i32).collect();
                e.submit(prompt, SamplingParams::greedy(16)).unwrap();
            }
            let t = Timer::start();
            let outs = e.run_to_completion(1_000_000).unwrap();
            let secs = t.elapsed_secs();
            let tokens: usize = outs.iter().map(|o| o.tokens.len()).sum();
            let model_pct = 100.0 * e.backend.model_ns as f64 / (secs * 1e9);
            println!(
                "xla serving max_batch={mb}: {:.1} tok/s ({model_pct:.1}% in model)",
                tokens as f64 / secs
            );
            tab3.set(ri, 0, tokens as f64 / secs);
            tab3.set(ri, 1, model_pct);
        }
    } else {
        println!("(skipping real-model part: artifacts/ missing or filtered)");
    }

    let tables = [tab1, tab2, tab3];
    write_markdown("serving_e2e", &[], &tables).unwrap();
    write_csv("serving_e2e", &tables).unwrap();
    println!("\nwrote bench_out/serving_e2e.md (+csv)");
}
