//! **Ablation A5** — the §V ad-hoc hybrid: mixed-size workloads through
//! `MultiPool` (sorted class table + spill + system fallback) vs straight
//! malloc. Reports speed, hit rate, and internal waste — the §VI
//! trade-off — plus the **spill arm**: one hot class pushed past its
//! capacity, spill-on-exhaustion vs the fail-fast (spill_hops = 0)
//! baseline, reporting spill rate and p99 alloc latency.
//!
//! Run: `cargo bench --bench ablate_multipool`
//!      `cargo bench --bench ablate_multipool -- spill --smoke` (CI)
//!
//! Writes `bench_out/ablate_multipool.{md,csv,json}`; the JSON summary
//! carries `spill_hot_total` (≥ 1: the hot scenario must spill) and
//! `spill_uncontended_total` (== 0: no spurious spill), which CI asserts.

use fastpool::bench_harness::{write_csv, write_json, write_markdown, ReportTable, Suite};
use fastpool::pool::{MultiPool, MultiPoolConfig};
use fastpool::util::json::{self, Json};
use fastpool::util::{LogHistogram, Rng, Timer, Zipf};

const OPS: usize = 400_000;
const LIVE_TARGET: usize = 1024;

#[derive(Clone, Copy)]
enum Mix {
    /// Zipf-distributed power-of-two-ish sizes, 8..2048 (asset-like).
    Zipf,
    /// Uniform 1..1024 (worst case for class rounding).
    Uniform,
    /// 90% exactly 64B, 10% uniform large (packet-like).
    Bimodal,
}

fn sample_size(mix: Mix, rng: &mut Rng, zipf: &Zipf) -> usize {
    match mix {
        Mix::Zipf => 8usize << zipf.sample(rng),
        Mix::Uniform => 1 + rng.gen_usize(0, 1024),
        Mix::Bimodal => {
            if rng.gen_bool(0.9) {
                64
            } else {
                2048 + rng.gen_usize(0, 4096)
            }
        }
    }
}

fn run_multipool(mix: Mix, ops: usize) -> (f64, f64, u64) {
    let mut mp = MultiPool::new(MultiPoolConfig {
        min_class: 16,
        max_class: 4096,
        blocks_per_class: LIVE_TARGET as u32 * 2,
        system_fallback: true,
        magazine_depth: 0, // MultiPool is single-threaded: no magazines
        ..Default::default()
    });
    let zipf = Zipf::new(9, 1.1);
    let mut rng = Rng::new(5);
    let mut live = Vec::with_capacity(LIVE_TARGET);
    let t = Timer::start();
    for _ in 0..ops {
        if live.is_empty() || (live.len() < LIVE_TARGET && rng.gen_bool(0.5)) {
            let size = sample_size(mix, &mut rng, &zipf);
            if let Some((p, _)) = mp.allocate(size) {
                live.push((p, size));
            }
        } else {
            let i = rng.gen_usize(0, live.len());
            let (p, size) = live.swap_remove(i);
            // SAFETY: `(p, size)` came from `allocate(size)` and was removed from
            // `live`, so it is freed exactly once.
            unsafe { mp.deallocate(p, size) };
        }
    }
    let ns = t.elapsed_ns() as f64 / ops as f64;
    for (p, size) in live.drain(..) {
        // SAFETY: the remaining live pairs were never freed in the loop above.
        unsafe { mp.deallocate(p, size) };
    }
    (ns, mp.pool_hit_rate(), mp.total_internal_waste())
}

fn run_malloc(mix: Mix, ops: usize) -> f64 {
    let zipf = Zipf::new(9, 1.1);
    let mut rng = Rng::new(5);
    let mut live: Vec<(*mut u8, usize)> = Vec::with_capacity(LIVE_TARGET);
    let t = Timer::start();
    for _ in 0..ops {
        if live.is_empty() || (live.len() < LIVE_TARGET && rng.gen_bool(0.5)) {
            let size = sample_size(mix, &mut rng, &zipf);
            // SAFETY: plain malloc; the pointer only travels to `free`.
            let p = unsafe { libc::malloc(size) } as *mut u8;
            live.push((p, size));
        } else {
            let i = rng.gen_usize(0, live.len());
            let (p, _) = live.swap_remove(i);
            // SAFETY: `p` came from `malloc` and was removed from `live`.
            unsafe { libc::free(p as *mut libc::c_void) };
        }
    }
    let ns = t.elapsed_ns() as f64 / ops as f64;
    for (p, _) in live.drain(..) {
        // SAFETY: the remaining malloc'd pointers were never freed above.
        unsafe { libc::free(p as *mut libc::c_void) };
    }
    ns
}

extern crate libc;

/// Spill-arm result: per-alloc latency histogram + end-of-run counters.
struct SpillRun {
    p50_ns: u64,
    p99_ns: u64,
    spill_total: u64,
    system_allocs: u64,
    spill_rate: f64,
}

/// One hot class (64 B) driven past its capacity while the larger
/// classes idle with room — the skewed-tenant scenario spill exists for.
/// `hops = 0` is the fail-fast baseline: exhaustion goes straight to the
/// system allocator. `live_target` beyond `blocks` forces exhaustion;
/// below it, the run is uncontended and must never spill.
fn run_spill(hops: u32, blocks: u32, live_target: usize, ops: usize) -> SpillRun {
    let mut mp = MultiPool::new(MultiPoolConfig {
        min_class: 16,
        max_class: 4096,
        blocks_per_class: blocks,
        system_fallback: true,
        magazine_depth: 0,
        spill_hops: hops,
        ..Default::default()
    });
    let mut rng = Rng::new(11);
    let mut live: Vec<(core::ptr::NonNull<u8>, usize)> = Vec::with_capacity(live_target);
    let mut hist = LogHistogram::new();
    let mut allocs = 0u64;
    for _ in 0..ops {
        if live.is_empty() || (live.len() < live_target && rng.gen_bool(0.6)) {
            // Hot class: every allocation asks for 64 B.
            let t = Timer::start();
            let got = mp.allocate(64);
            hist.record(t.elapsed_ns().max(1));
            allocs += 1;
            if let Some((p, _)) = got {
                live.push((p, 64));
            }
        } else {
            let i = rng.gen_usize(0, live.len());
            let (p, size) = live.swap_remove(i);
            // SAFETY: `(p, size)` came from `allocate(size)` and was removed from
            // `live`, so it is freed exactly once.
            unsafe { mp.deallocate(p, size) };
        }
    }
    for (p, size) in live.drain(..) {
        // SAFETY: the remaining live pairs were never freed in the loop above.
        unsafe { mp.deallocate(p, size) };
    }
    let spill_total = mp.spill_total();
    SpillRun {
        p50_ns: hist.percentile(50.0),
        p99_ns: hist.percentile(99.0),
        spill_total,
        system_allocs: mp.system_allocs,
        spill_rate: if allocs == 0 { 0.0 } else { spill_total as f64 / allocs as f64 },
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ops = if smoke { 40_000 } else { OPS };
    let suite = Suite::new("multipool");
    let mixes = [("zipf", Mix::Zipf), ("uniform", Mix::Uniform), ("bimodal", Mix::Bimodal)];
    let mut tab = ReportTable::new(
        "A5: MultiPool (size classes + fallback) vs malloc on mixed sizes",
        "size mix",
        mixes.iter().map(|(n, _)| n.to_string()).collect(),
        vec![
            "multipool ns/op".into(),
            "malloc ns/op".into(),
            "speedup".into(),
            "hit rate %".into(),
            "waste MiB".into(),
        ],
        "median of 5 runs",
    );

    for (ri, (name, mix)) in mixes.iter().enumerate() {
        if !suite.enabled(name) {
            continue;
        }
        let med = |f: &dyn Fn() -> f64| {
            let mut xs: Vec<f64> = (0..5).map(|_| f()).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[2]
        };
        let (mp_ns, hit, waste) = {
            let mut runs: Vec<(f64, f64, u64)> =
                (0..5).map(|_| run_multipool(*mix, ops)).collect();
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            runs[2]
        };
        let malloc_ns = med(&|| run_malloc(*mix, ops));
        println!(
            "{name:<8} multipool {mp_ns:>6.1} ns | malloc {malloc_ns:>6.1} ns | {:>4.1}x | hit {:>5.1}% | waste {:.1} MiB",
            malloc_ns / mp_ns,
            hit * 100.0,
            waste as f64 / (1 << 20) as f64
        );
        tab.set(ri, 0, mp_ns);
        tab.set(ri, 1, malloc_ns);
        tab.set(ri, 2, malloc_ns / mp_ns);
        tab.set(ri, 3, hit * 100.0);
        tab.set(ri, 4, waste as f64 / (1 << 20) as f64);
    }

    // Spill arm: hot 64B class, capacity 512 blocks, ~768 live wanted →
    // exhausted; classes 128/256 idle with room. Three scenarios:
    //   spill      — spill_hops=2, overflow rides the larger classes
    //   failfast   — spill_hops=0, overflow goes to the system allocator
    //   uncontended— live fits in class capacity, spill must stay 0
    let mut spill_tab = ReportTable::new(
        "A5b: spill-on-exhaustion vs fail-fast (hot 64B class over capacity)",
        "scenario",
        vec!["spill".into(), "failfast".into(), "uncontended".into()],
        vec![
            "p50 ns".into(),
            "p99 ns".into(),
            "spill_total".into(),
            "system allocs".into(),
            "spill rate %".into(),
        ],
        "single-threaded MultiPool, 60/40 alloc/free at the live target",
    );
    let mut spill_summary: Vec<(&str, Json)> = Vec::new();
    if suite.enabled("spill") {
        let blocks = 512u32;
        let hot = run_spill(2, blocks, 768, ops);
        let failfast = run_spill(0, blocks, 768, ops);
        let uncontended = run_spill(2, blocks, 256, ops);
        assert!(
            hot.spill_total >= 1,
            "hot scenario must spill (got {})",
            hot.spill_total
        );
        assert_eq!(
            uncontended.spill_total, 0,
            "uncontended scenario must never spill"
        );
        assert_eq!(failfast.spill_total, 0, "fail-fast arm has spill disabled");
        for (ri, r) in [&hot, &failfast, &uncontended].into_iter().enumerate() {
            spill_tab.set(ri, 0, r.p50_ns as f64);
            spill_tab.set(ri, 1, r.p99_ns as f64);
            spill_tab.set(ri, 2, r.spill_total as f64);
            spill_tab.set(ri, 3, r.system_allocs as f64);
            spill_tab.set(ri, 4, r.spill_rate * 100.0);
        }
        println!(
            "spill     p99 {:>6} ns | {} spills ({:.2}% of allocs) | {} system allocs",
            hot.p99_ns,
            hot.spill_total,
            hot.spill_rate * 100.0,
            hot.system_allocs
        );
        println!(
            "failfast  p99 {:>6} ns | {} spills | {} system allocs",
            failfast.p99_ns, failfast.spill_total, failfast.system_allocs
        );
        println!(
            "uncontend p99 {:>6} ns | {} spills | {} system allocs",
            uncontended.p99_ns, uncontended.spill_total, uncontended.system_allocs
        );
        spill_summary.extend([
            ("spill_hot_total", Json::Num(hot.spill_total as f64)),
            ("spill_hot_rate", Json::Num(hot.spill_rate)),
            ("spill_hot_p99_ns", Json::Num(hot.p99_ns as f64)),
            ("spill_hot_system_allocs", Json::Num(hot.system_allocs as f64)),
            ("failfast_p99_ns", Json::Num(failfast.p99_ns as f64)),
            ("failfast_system_allocs", Json::Num(failfast.system_allocs as f64)),
            ("spill_uncontended_total", Json::Num(uncontended.spill_total as f64)),
        ]);
    }

    let mut summary = vec![
        ("ops", Json::Num(ops as f64)),
        ("smoke", Json::Bool(smoke)),
        ("mode", json::s("single-threaded MultiPool vs malloc + spill ablation")),
    ];
    summary.extend(spill_summary);

    let tables = [tab, spill_tab];
    write_markdown("ablate_multipool", &[], &tables).unwrap();
    write_csv("ablate_multipool", &tables).unwrap();
    write_json("ablate_multipool", &tables, &summary).unwrap();
    println!("wrote bench_out/ablate_multipool.json (+md, csv)");
}
