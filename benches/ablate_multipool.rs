//! **Ablation A5** — the §V ad-hoc hybrid: mixed-size workloads through
//! `MultiPool` (size classes + system fallback) vs straight malloc.
//! Reports speed, hit rate, and internal waste — the §VI trade-off.
//!
//! Run: `cargo bench --bench ablate_multipool`

use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::pool::{MultiPool, MultiPoolConfig};
use fastpool::util::{Rng, Timer, Zipf};

const OPS: usize = 400_000;
const LIVE_TARGET: usize = 1024;

#[derive(Clone, Copy)]
enum Mix {
    /// Zipf-distributed power-of-two-ish sizes, 8..2048 (asset-like).
    Zipf,
    /// Uniform 1..1024 (worst case for class rounding).
    Uniform,
    /// 90% exactly 64B, 10% uniform large (packet-like).
    Bimodal,
}

fn sample_size(mix: Mix, rng: &mut Rng, zipf: &Zipf) -> usize {
    match mix {
        Mix::Zipf => 8usize << zipf.sample(rng),
        Mix::Uniform => 1 + rng.gen_usize(0, 1024),
        Mix::Bimodal => {
            if rng.gen_bool(0.9) {
                64
            } else {
                2048 + rng.gen_usize(0, 4096)
            }
        }
    }
}

fn run_multipool(mix: Mix) -> (f64, f64, u64) {
    let mut mp = MultiPool::new(MultiPoolConfig {
        min_class: 16,
        max_class: 4096,
        blocks_per_class: LIVE_TARGET as u32 * 2,
        system_fallback: true,
        magazine_depth: 0, // MultiPool is single-threaded: no magazines
    });
    let zipf = Zipf::new(9, 1.1);
    let mut rng = Rng::new(5);
    let mut live = Vec::with_capacity(LIVE_TARGET);
    let t = Timer::start();
    for _ in 0..OPS {
        if live.is_empty() || (live.len() < LIVE_TARGET && rng.gen_bool(0.5)) {
            let size = sample_size(mix, &mut rng, &zipf);
            if let Some((p, o)) = mp.allocate(size) {
                live.push((p, size, o));
            }
        } else {
            let i = rng.gen_usize(0, live.len());
            let (p, size, o) = live.swap_remove(i);
            unsafe { mp.deallocate(p, size, o) };
        }
    }
    let ns = t.elapsed_ns() as f64 / OPS as f64;
    for (p, size, o) in live.drain(..) {
        unsafe { mp.deallocate(p, size, o) };
    }
    (ns, mp.pool_hit_rate(), mp.total_internal_waste())
}

fn run_malloc(mix: Mix) -> f64 {
    let zipf = Zipf::new(9, 1.1);
    let mut rng = Rng::new(5);
    let mut live: Vec<(*mut u8, usize)> = Vec::with_capacity(LIVE_TARGET);
    let t = Timer::start();
    for _ in 0..OPS {
        if live.is_empty() || (live.len() < LIVE_TARGET && rng.gen_bool(0.5)) {
            let size = sample_size(mix, &mut rng, &zipf);
            let p = unsafe { libc::malloc(size) } as *mut u8;
            live.push((p, size));
        } else {
            let i = rng.gen_usize(0, live.len());
            let (p, _) = live.swap_remove(i);
            unsafe { libc::free(p as *mut libc::c_void) };
        }
    }
    let ns = t.elapsed_ns() as f64 / OPS as f64;
    for (p, _) in live.drain(..) {
        unsafe { libc::free(p as *mut libc::c_void) };
    }
    ns
}

extern crate libc;

fn main() {
    let suite = Suite::new("multipool");
    let mixes = [("zipf", Mix::Zipf), ("uniform", Mix::Uniform), ("bimodal", Mix::Bimodal)];
    let mut tab = ReportTable::new(
        "A5: MultiPool (size classes + fallback) vs malloc on mixed sizes",
        "size mix",
        mixes.iter().map(|(n, _)| n.to_string()).collect(),
        vec![
            "multipool ns/op".into(),
            "malloc ns/op".into(),
            "speedup".into(),
            "hit rate %".into(),
            "waste MiB".into(),
        ],
        "median of 5 runs",
    );

    for (ri, (name, mix)) in mixes.iter().enumerate() {
        if !suite.enabled(name) {
            continue;
        }
        let med = |f: &dyn Fn() -> f64| {
            let mut xs: Vec<f64> = (0..5).map(|_| f()).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[2]
        };
        let (mp_ns, hit, waste) = {
            let mut runs: Vec<(f64, f64, u64)> = (0..5).map(|_| run_multipool(*mix)).collect();
            runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            runs[2]
        };
        let malloc_ns = med(&|| run_malloc(*mix));
        println!(
            "{name:<8} multipool {mp_ns:>6.1} ns | malloc {malloc_ns:>6.1} ns | {:>4.1}x | hit {:>5.1}% | waste {:.1} MiB",
            malloc_ns / mp_ns,
            hit * 100.0,
            waste as f64 / (1 << 20) as f64
        );
        tab.set(ri, 0, mp_ns);
        tab.set(ri, 1, malloc_ns);
        tab.set(ri, 2, malloc_ns / mp_ns);
        tab.set(ri, 3, hit * 100.0);
        tab.set(ri, 4, waste as f64 / (1 << 20) as f64);
    }

    write_markdown("ablate_multipool", &[], &[tab.clone()]).unwrap();
    write_csv("ablate_multipool", &[tab]).unwrap();
    println!("wrote bench_out/ablate_multipool.md (+csv)");
}
