//! **Figure 4 reproduction** — "Running outside the debugger – standalone:
//! (a) system malloc and, (b) custom pool", plus the §VIII headline ratio
//! table (E2–E4 in DESIGN.md).
//!
//! Each line of the paper's figure is a fixed allocation size; the x-axis
//! is the number of allocations; the y-axis total time. We sweep the same
//! grid with the same inner loop (allocate n chunks, free them all),
//! report median total ms per cell for malloc and pool, and the derived
//! speedup table. Output: bench_out/fig4*.{md,csv}.
//!
//! Run: `cargo bench --bench fig4_malloc_vs_pool` (optionally with a
//! substring filter argument).

use fastpool::alloc::{AllocHandle, BenchAllocator, PoolAllocator, SystemAllocator};
use fastpool::bench_harness::{write_csv, write_markdown, BenchResult, ReportTable, Suite};
use fastpool::util::black_box;

const SIZES: &[u32] = &[16, 32, 64, 128, 256, 512, 1024, 4096];
const COUNTS: &[u32] = &[1_000, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000];

fn labels_counts() -> Vec<String> {
    COUNTS.iter().map(|c| c.to_string()).collect()
}

fn labels_sizes() -> Vec<String> {
    SIZES.iter().map(|s| format!("{s}B")).collect()
}

/// The paper's inner loop: allocate `n` chunks of `size`, then free all.
/// Returns handles vec reused across iterations to avoid re-allocating the
/// bookkeeping array inside the timed region.
fn run_cycle(a: &mut dyn BenchAllocator, n: u32, size: u32, held: &mut Vec<AllocHandle>) {
    for _ in 0..n {
        match a.alloc(size as usize) {
            Some(h) => held.push(h),
            None => break,
        }
    }
    for h in held.drain(..) {
        a.free(h);
    }
}

fn main() {
    let mut suite = Suite::new("fig4");
    // Fewer samples: each iteration is a full n-alloc cycle.
    suite.bencher = fastpool::bench_harness::Bencher::new(
        fastpool::bench_harness::runner::BenchConfig {
            warmup_ns: 20_000_000,
            sample_target_ns: 25_000_000,
            samples: 12,
            max_total_iters: u64::MAX,
        },
    );

    let mut tab_malloc = ReportTable::new(
        "Figure 4(a): system malloc, standalone (total ms per cycle)",
        "allocations",
        labels_counts(),
        labels_sizes(),
        "ms per alloc-all-free-all cycle (median)",
    );
    let mut tab_pool = ReportTable::new(
        "Figure 4(b): fixed-size pool (total ms per cycle)",
        "allocations",
        labels_counts(),
        labels_sizes(),
        "ms per alloc-all-free-all cycle (median)",
    );
    let mut tab_speedup = ReportTable::new(
        "§VIII headline: malloc time / pool time",
        "allocations",
        labels_counts(),
        labels_sizes(),
        "x (higher = pool faster)",
    );
    let mut results: Vec<BenchResult> = Vec::new();

    for (ci, &size) in SIZES.iter().enumerate() {
        for (ri, &n) in COUNTS.iter().enumerate() {
            let name_m = format!("malloc/n={n}/size={size}");
            let name_p = format!("pool/n={n}/size={size}");
            if !suite.enabled(&name_m) && !suite.enabled(&name_p) {
                continue;
            }
            let mut held = Vec::with_capacity(n as usize);

            let mut malloc = SystemAllocator::new();
            let rm = suite.bencher.bench_with_elements(&name_m, n as u64, &mut || {
                run_cycle(&mut malloc, n, size, &mut held);
                black_box(&mut held);
            });
            println!("{}", rm.one_line());

            let mut pool = PoolAllocator::new(size as usize, n);
            let rp = suite.bencher.bench_with_elements(&name_p, n as u64, &mut || {
                run_cycle(&mut pool, n, size, &mut held);
                black_box(&mut held);
            });
            println!("{}", rp.one_line());

            tab_malloc.set(ri, ci, rm.summary.median / 1e6);
            tab_pool.set(ri, ci, rp.summary.median / 1e6);
            tab_speedup.set(ri, ci, rm.summary.median / rp.summary.median);
            results.push(rm);
            results.push(rp);
        }
    }

    // Headline summary (geometric mean of speedups over the grid).
    let ratios: Vec<f64> = tab_speedup
        .cells
        .iter()
        .flatten()
        .copied()
        .filter(|v| !v.is_nan())
        .collect();
    if !ratios.is_empty() {
        let gm = fastpool::util::geomean(&ratios);
        let max = ratios.iter().cloned().fold(f64::MIN, f64::max);
        println!("\n== Figure 4 summary ==");
        println!("pool vs malloc speedup: geomean {gm:.1}x, max {max:.1}x over the grid");
        println!("(paper: \"ten times faster than the general system allocator\")");
    }

    let tables = [tab_malloc, tab_pool, tab_speedup];
    write_markdown("fig4_malloc_vs_pool", &results, &tables).unwrap();
    write_csv("fig4_malloc_vs_pool", &tables).unwrap();
    println!("\nwrote bench_out/fig4_malloc_vs_pool.md (+csv)");
}
