//! **Ablation A2** — access-pattern generality: LIFO / FIFO / random-churn
//! / steady-state / game-frame traces across the full allocator zoo (paper
//! pool, eager pool, pointer free-list, malloc, first-fit, buddy).
//!
//! Run: `cargo bench --bench ablate_churn`

use fastpool::alloc::{
    BenchAllocator, BuddyAllocator, EagerPoolAllocator, FirstFitAllocator,
    PoolAllocator, PtrPoolAllocator, SystemAllocator,
};
use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::workload::{game, patterns, replay, SizeDist, Trace};

const SIZE: u32 = 64;

fn traces() -> Vec<(&'static str, Trace)> {
    vec![
        ("pairs", patterns::alloc_free_pairs(20_000, SIZE)),
        ("lifo", patterns::lifo(512, 40, SIZE)),
        ("fifo", patterns::fifo(512, 40, SIZE)),
        ("churn", patterns::random_churn(40_000, 512, SizeDist::Fixed(SIZE), 11)),
        ("steady", patterns::steady_state(512, 20_000, SizeDist::Fixed(SIZE), 12)),
        ("game", {
            let cfg = game::GameConfig {
                frames: 300,
                particle_size: SIZE,
                packet_size: SIZE,
                asset_size: SIZE,
                ..Default::default()
            };
            game::generate(cfg, 13).0
        }),
    ]
}

fn allocators(peak: u32) -> Vec<Box<dyn BenchAllocator>> {
    let cap = peak + 64;
    vec![
        Box::new(PoolAllocator::new(SIZE as usize, cap)),
        Box::new(EagerPoolAllocator::new(SIZE as usize, cap)),
        Box::new(PtrPoolAllocator::new(SIZE as usize, cap)),
        Box::new(SystemAllocator::new()),
        Box::new(FirstFitAllocator::new((cap as usize) * (SIZE as usize) * 2)),
        Box::new(BuddyAllocator::new((cap as usize) * (SIZE as usize) * 4)),
    ]
}

fn main() {
    let suite = Suite::new("churn");
    let traces = traces();
    let names: Vec<&str> =
        vec!["pool", "pool-eager", "pool-ptrlist", "malloc", "firstfit", "buddy"];

    let mut tab = ReportTable::new(
        "A2: ns/op by access pattern × allocator (64B requests)",
        "pattern",
        traces.iter().map(|(n, _)| n.to_string()).collect(),
        names.iter().map(|s| s.to_string()).collect(),
        "ns per op (median of 9 replays)",
    );

    for (ri, (tname, trace)) in traces.iter().enumerate() {
        for (ci, alloc) in allocators(trace.peak_live).iter_mut().enumerate() {
            let bench_name = format!("{tname}/{}", names[ci]);
            if !suite.enabled(&bench_name) {
                continue;
            }
            // Warm twice, then take the median of 9 replays.
            replay(trace, alloc.as_mut());
            replay(trace, alloc.as_mut());
            let mut per_op: Vec<f64> =
                (0..9).map(|_| replay(trace, alloc.as_mut()).ns_per_op()).collect();
            per_op.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = per_op[per_op.len() / 2];
            println!("{bench_name:<24} {med:>8.1} ns/op");
            tab.set(ri, ci, med);
        }
    }

    // Derived: pool speedup per pattern.
    println!("\n== A2 summary (pool vs malloc) ==");
    for (ri, (tname, _)) in traces.iter().enumerate() {
        let pool = tab.cells[ri][0];
        let malloc = tab.cells[ri][3];
        if !pool.is_nan() && !malloc.is_nan() {
            println!("  {tname:<8} {:>5.1}x", malloc / pool);
        }
    }

    write_markdown("ablate_churn", &[], &[tab.clone()]).unwrap();
    write_csv("ablate_churn", &[tab]).unwrap();
    println!("wrote bench_out/ablate_churn.md (+csv)");
}
