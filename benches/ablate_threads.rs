//! **Ablation A3** — the §VI threading question: Mutex-wrapped pool vs the
//! lock-free single-head Treiber pool vs the sharded pool vs raw malloc,
//! at 1–8 threads of alloc/free pairs on a shared pool.
//!
//! The sharded arm is the point of the ablation: the single packed head of
//! `AtomicPool` serialises every CAS on one cache line, while
//! `ShardedPool` gives each thread a home shard (8 shards here), so pairs
//! stay core-local and throughput scales instead of collapsing.
//!
//! **A3b (skewed affinity)** — the shard-topology question: every worker
//! starts homed on shard 0 of an 8-shard pool (a `Pinned::all(0)` base —
//! the worst placement a NUMA-oblivious runtime can hand you) and keeps a
//! working set that shard 0 cannot hold. The static arm pays a steal scan
//! tax forever; the `StealAware` arm rehomes threads to their dominant
//! victims and reports the rehome count and post-rehome (phase-2)
//! local-hit rate.
//!
//! Run: `cargo bench --bench ablate_threads` (arg 1 filters by name, e.g.
//! `skew`; `--smoke` shrinks iteration counts for CI).
//! Output: bench_out/ablate_threads.{md,csv,json} — the JSON carries the
//! raw grid, the 8-thread sharded-vs-atomic speedup headline and the
//! skewed-affinity rehome/local-hit summary.

use std::sync::Arc;

use fastpool::bench_harness::{write_csv, write_json, write_markdown, ReportTable, Suite};
use fastpool::pool::{
    AtomicPool, LockedPool, Pinned, PoolConfig, ShardPlacement, ShardedPool, StealAware,
};
use fastpool::testkit::skew::{run_skewed_affinity, SkewConfig, SkewOutcome};
use fastpool::util::json::Json;
use fastpool::util::Timer;

const THREADS: &[usize] = &[1, 2, 4, 8];
const OPS_PER_THREAD: usize = 200_000;
const BLOCK: usize = 64;
const POOL_BLOCKS: u32 = 4096;
const SHARDS: usize = 8;

fn bench_locked(threads: usize) -> f64 {
    let pool = Arc::new(LockedPool::new(PoolConfig::new(BLOCK, POOL_BLOCKS)));
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    if let Some(p) = pool.allocate() {
                        // SAFETY: `p` came from `allocate` and is freed exactly once.
                        unsafe { pool.deallocate(p) };
                    }
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

fn bench_atomic(threads: usize) -> f64 {
    let pool = Arc::new(AtomicPool::with_blocks(BLOCK, POOL_BLOCKS));
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    if let Some(idx) = pool.allocate_index() {
                        pool.deallocate_index(idx);
                    }
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

fn sharded_run(threads: usize) -> (f64, f64) {
    let pool = Arc::new(ShardedPool::with_shards(BLOCK, POOL_BLOCKS, SHARDS));
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    if let Some(p) = pool.allocate() {
                        // SAFETY: `p` came from `allocate` and is freed exactly once.
                        unsafe { pool.deallocate(p) };
                    }
                }
            });
        }
    });
    let ns = t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64;
    (ns, pool.stats().steal_rate())
}

fn bench_malloc(threads: usize) -> f64 {
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    // SAFETY: plain malloc; the pointer is only passed straight to `free`.
                    let p = unsafe { libc::malloc(BLOCK) };
                    std::hint::black_box(p);
                    // SAFETY: `p` came from `malloc` above and is freed exactly once.
                    unsafe { libc::free(p) };
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

fn main() {
    let suite = Suite::new("threads");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut tab = ReportTable::new(
        "A3: alloc+free pair latency under contention (shared 4096x64B pool)",
        "threads",
        THREADS.iter().map(|t| t.to_string()).collect(),
        vec![
            "mutex pool".into(),
            "lock-free pool".into(),
            "sharded pool".into(),
            "malloc".into(),
        ],
        "ns per pair (median of 7 runs)",
    );

    let median = |f: &dyn Fn(usize) -> f64, threads: usize| -> f64 {
        let mut xs: Vec<f64> = (0..7).map(|_| f(threads)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };

    let max_threads = *THREADS.last().unwrap();
    let mut atomic_at = vec![f64::NAN; THREADS.len()];
    let mut sharded_at = vec![f64::NAN; THREADS.len()];
    let mut steal_rate_max_t = f64::NAN;
    for (ri, &threads) in THREADS.iter().enumerate() {
        if !suite.enabled(&format!("threads={threads}")) {
            continue;
        }
        let ml = median(&bench_locked, threads);
        let ma = median(&bench_atomic, threads);
        // One loop feeds both the timing median and the steal rate — no
        // extra throwaway run.
        let mut pairs: Vec<(f64, f64)> = (0..7).map(|_| sharded_run(threads)).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (ms, steal) = pairs[pairs.len() / 2];
        if threads == max_threads {
            steal_rate_max_t = steal;
        }
        let mm = median(&bench_malloc, threads);
        println!(
            "threads={threads}: mutex {ml:>7.1} ns | lock-free {ma:>7.1} ns | \
             sharded {ms:>7.1} ns | malloc {mm:>7.1} ns"
        );
        atomic_at[ri] = ma;
        sharded_at[ri] = ms;
        tab.set(ri, 0, ml);
        tab.set(ri, 1, ma);
        tab.set(ri, 2, ms);
        tab.set(ri, 3, mm);
    }

    let last = THREADS.len() - 1;
    let speedup = atomic_at[last] / sharded_at[last];
    println!("\n== A3 summary ==");
    println!("single-head lock-free serialises every op on one CAS cache line; the");
    println!("sharded pool keeps pairs core-local (home shard per thread, stealing");
    println!("only on exhaustion), so it scales with cores like malloc's tcache.");
    if speedup.is_finite() {
        println!(
            "at {max_threads} threads: sharded is {speedup:.2}x the single-head pool \
             (steal rate {:.2}%).",
            steal_rate_max_t * 100.0
        );
    }

    // ---- A3b: skewed affinity (steal-aware rehoming vs static) ---------
    // Same `testkit::skew` workload the acceptance stress test asserts on.
    let skew_cfg = SkewConfig {
        phase_ops: if smoke { 2_000 } else { SkewConfig::default().phase_ops },
        ..Default::default()
    };
    let mut skew_tab = ReportTable::new(
        "A3b: skewed affinity — all workers homed on shard 0, phase-2 measurements",
        "placement",
        vec!["pinned-static".into(), "steal-aware".into()],
        vec!["local_hit_pct".into(), "steal_scans_per_1k".into(), "rehomes".into()],
        "phase-2 local-hit % / steal scans per 1k allocs / rehome count",
    );
    type PlacementFactory = fn() -> Arc<dyn ShardPlacement>;
    let mut skew_results: Vec<(&'static str, SkewOutcome)> = Vec::new();
    let arms: [(&'static str, PlacementFactory); 2] = [
        ("skew=pinned-static", || Arc::new(Pinned::all(0))),
        ("skew=steal-aware", || Arc::new(StealAware::over(Arc::new(Pinned::all(0))))),
    ];
    for (ri, (name, make)) in arms.iter().enumerate() {
        if !suite.enabled(name) {
            continue;
        }
        let r = run_skewed_affinity(make(), skew_cfg);
        println!(
            "{name}: local {:>5.1}% | {:>6.1} steal scans/1k allocs | {} rehomes",
            100.0 * r.local_rate(),
            r.scans_per_1k(),
            r.rehomes
        );
        skew_tab.set(ri, 0, 100.0 * r.local_rate());
        skew_tab.set(ri, 1, r.scans_per_1k());
        skew_tab.set(ri, 2, r.rehomes as f64);
        skew_results.push((*name, r));
    }

    // Only finite numbers go into the JSON summary (a name filter can skip
    // the max-thread row, leaving these NaN — and NaN is not valid JSON).
    let mut summary = vec![
        ("shards", Json::Num(SHARDS as f64)),
        ("ops_per_thread", Json::Num(OPS_PER_THREAD as f64)),
        ("skew_phase_ops", Json::Num(skew_cfg.phase_ops as f64)),
    ];
    if speedup.is_finite() {
        summary.push(("sharded_vs_atomic_speedup_8t", Json::Num(speedup)));
    }
    if steal_rate_max_t.is_finite() {
        summary.push(("sharded_steal_rate_8t", Json::Num(steal_rate_max_t)));
    }
    for (name, r) in &skew_results {
        match *name {
            "skew=pinned-static" => {
                summary
                    .push(("skew_static_local_hit_pct", Json::Num(100.0 * r.local_rate())));
                summary.push(("skew_static_scans_per_1k", Json::Num(r.scans_per_1k())));
            }
            _ => {
                summary
                    .push(("skew_aware_local_hit_pct", Json::Num(100.0 * r.local_rate())));
                summary.push(("skew_aware_scans_per_1k", Json::Num(r.scans_per_1k())));
                summary.push(("skew_rehomes", Json::Num(r.rehomes as f64)));
            }
        }
    }

    write_markdown("ablate_threads", &[], &[tab.clone(), skew_tab.clone()]).unwrap();
    write_csv("ablate_threads", &[tab.clone(), skew_tab.clone()]).unwrap();
    write_json("ablate_threads", &[tab, skew_tab], &summary).unwrap();
    println!("wrote bench_out/ablate_threads.md (+csv, +json)");
}
