//! **Ablation A3** — the §VI threading question: Mutex-wrapped pool vs the
//! lock-free Treiber pool vs raw malloc, at 1–8 threads of alloc/free
//! pairs on a shared pool.
//!
//! Run: `cargo bench --bench ablate_threads`

use std::sync::Arc;

use fastpool::bench_harness::{write_csv, write_markdown, ReportTable, Suite};
use fastpool::pool::{AtomicPool, LockedPool, PoolConfig};
use fastpool::util::Timer;

const THREADS: &[usize] = &[1, 2, 4, 8];
const OPS_PER_THREAD: usize = 200_000;
const BLOCK: usize = 64;
const POOL_BLOCKS: u32 = 4096;

fn bench_locked(threads: usize) -> f64 {
    let pool = Arc::new(LockedPool::new(PoolConfig::new(BLOCK, POOL_BLOCKS)));
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    if let Some(p) = pool.allocate() {
                        unsafe { pool.deallocate(p) };
                    }
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

fn bench_atomic(threads: usize) -> f64 {
    let pool = Arc::new(AtomicPool::with_blocks(BLOCK, POOL_BLOCKS));
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    if let Some(idx) = pool.allocate_index() {
                        pool.deallocate_index(idx);
                    }
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

fn bench_malloc(threads: usize) -> f64 {
    let t = Timer::start();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || {
                for _ in 0..OPS_PER_THREAD {
                    let p = unsafe { libc::malloc(BLOCK) };
                    std::hint::black_box(p);
                    unsafe { libc::free(p) };
                }
            });
        }
    });
    t.elapsed_ns() as f64 / (threads * OPS_PER_THREAD) as f64
}

// The bench binary links libc via the fastpool crate.
use fastpool as _;
extern crate libc;

fn main() {
    let suite = Suite::new("threads");
    let mut tab = ReportTable::new(
        "A3: alloc+free pair latency under contention (shared 4096x64B pool)",
        "threads",
        THREADS.iter().map(|t| t.to_string()).collect(),
        vec!["mutex pool".into(), "lock-free pool".into(), "malloc".into()],
        "ns per pair (median of 7 runs)",
    );

    let median = |f: &dyn Fn(usize) -> f64, threads: usize| -> f64 {
        let mut xs: Vec<f64> = (0..7).map(|_| f(threads)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };

    for (ri, &threads) in THREADS.iter().enumerate() {
        if !suite.enabled(&format!("threads={threads}")) {
            continue;
        }
        let ml = median(&bench_locked, threads);
        let ma = median(&bench_atomic, threads);
        let mm = median(&bench_malloc, threads);
        println!(
            "threads={threads}: mutex {ml:>7.1} ns | lock-free {ma:>7.1} ns | malloc {mm:>7.1} ns"
        );
        tab.set(ri, 0, ml);
        tab.set(ri, 1, ma);
        tab.set(ri, 2, mm);
    }

    println!("\n== A3 summary ==");
    println!("lock-free scales where the mutex serialises; malloc uses per-thread");
    println!("tcache so it stays flat — the pool matches it only with the lock-free");
    println!("variant (the paper's 'further work', built here).");

    write_markdown("ablate_threads", &[], &[tab.clone()]).unwrap();
    write_csv("ablate_threads", &[tab]).unwrap();
    println!("wrote bench_out/ablate_threads.md (+csv)");
}
